//! Sparse (inducing-point) Gaussian processes — the large-budget surrogate
//! subsystem.
//!
//! The dense [`crate::model::gp::Gp`] pays O(n²) per prediction and O(n³)
//! per hyper-parameter refit, which caps BO runs at a few thousand
//! observations. This module trades a controlled approximation for
//! n-independent prediction cost, behind the same [`crate::model::Model`]
//! trait, so it drops into [`crate::bayes_opt::BOptimizer`], the
//! [`crate::baseline`] comparator, and the ask/tell
//! [`crate::coordinator::AskTellServer`] unchanged.
//!
//! # Method
//!
//! Pick `m << n` inducing locations `Z` (greedy max-min from the data,
//! [`inducing::InducingSet`]). With `K_mm = k(Z, Z)`, `K_nm = k(X, Z)` and
//! the FITC (Snelson & Ghahramani, 2006) heteroscedastic correction
//!
//! ```text
//! lambda_i = k(x_i, x_i) - k_i^T K_mm^{-1} k_i + sigma_n^2,
//! Lambda   = diag(lambda_1 .. lambda_n),
//! A        = K_mm + K_mn Lambda^{-1} K_nm,
//! alpha    = A^{-1} K_mn Lambda^{-1} (y - m(X)),
//! ```
//!
//! the posterior at a test point `x*` with `k* = k(Z, x*)` is
//!
//! ```text
//! mu(x*)     = m(x*) + k*^T alpha                      (SoR mean)
//! sigma²(x*) = k(x*,x*) - k*^T K_mm^{-1} k* + k*^T A^{-1} k*   (FITC)
//! ```
//!
//! Both m×m systems are solved through Cholesky factors; the n-row
//! reduction building `A` streams through blocked low-rank kernels in
//! [`crate::la::lowrank`].
//!
//! Hyper-parameters are fit by ML-II on the **exact FITC marginal
//! likelihood**: [`fitc::SparseGp::log_marginal_likelihood`] evaluates
//! `log N(y | m(X), Q_nn + Λ)` from the cached Woodbury factors in
//! O(n·m), and [`fitc::SparseGp::lml_grad`] contracts the trace weights
//! of `½ tr((μμᵀ − Σ⁻¹) dΣ)` against batched kernel-gradient blocks
//! ([`crate::kernel::Kernel::grad_params_block`]) in O(n·m² + m³) — the
//! same [`crate::model::hp_opt::KernelLFOpt`] iRprop⁻ machinery as the
//! dense GP, generic over [`crate::model::hp_opt::LmlModel`].
//!
//! # Complexity
//!
//! | operation                    | dense `Gp`      | [`SparseGp`]          |
//! |------------------------------|-----------------|-----------------------|
//! | batch `fit`                  | O(n³)           | O(n·m²)               |
//! | `add_sample` (amortized)     | O(n²)           | O(n·m + m³)           |
//! | `predict` mean               | O(n)            | O(m)                  |
//! | `predict` variance           | O(n²)           | O(m²)                 |
//! | `optimize_hyperparams`       | O(n³) per step  | O(n·m²) per step      |
//! | memory                       | O(n²)           | O(n·m + m²)           |
//!
//! # Choosing a model
//!
//! [`AdaptiveModel`] starts dense (exact, best for the small-n regime
//! every BO run begins in) and migrates to [`SparseGp`] once the
//! observation count crosses a configurable threshold — the default
//! surrogate for the long-running service path.

pub mod fitc;
pub mod inducing;

pub use fitc::{SgpConfig, SparseGp};
pub use inducing::{InducingSet, InducingUpdate};

use crate::kernel::Kernel;
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::serde::{ModelState, StateModel};
use crate::model::Model;

/// Default observation count at which [`AdaptiveModel`] goes sparse.
pub const DEFAULT_SPARSE_THRESHOLD: usize = 256;

#[derive(Clone)]
enum AdaptiveInner<K: Kernel, M: MeanFn> {
    Dense(Gp<K, M>),
    Sparse(SparseGp<K, M>),
}

/// A surrogate that is exact while small and sparse once large: wraps a
/// dense [`Gp`] and migrates to a [`SparseGp`] (carrying over data and
/// current hyper-parameters) when `n` crosses the threshold.
#[derive(Clone)]
pub struct AdaptiveModel<K: Kernel, M: MeanFn> {
    inner: AdaptiveInner<K, M>,
    threshold: usize,
    config: SgpConfig,
}

impl<K: Kernel, M: MeanFn> AdaptiveModel<K, M> {
    /// Start dense with the default threshold
    /// ([`DEFAULT_SPARSE_THRESHOLD`]) and sparse config.
    pub fn new(kernel: K, mean: M, noise: f64) -> Self {
        Self {
            inner: AdaptiveInner::Dense(Gp::new(kernel, mean, noise)),
            threshold: DEFAULT_SPARSE_THRESHOLD,
            config: SgpConfig::default(),
        }
    }

    /// Override the dense→sparse switch-over observation count.
    pub fn with_threshold(mut self, threshold: usize) -> Self {
        self.threshold = threshold.max(1);
        self
    }

    /// Override the sparse-side configuration.
    pub fn with_sparse_config(mut self, config: SgpConfig) -> Self {
        self.config = config;
        self
    }

    /// Override the ML-II hyper-opt settings on the current inner model
    /// (the dense→sparse migration carries them over, see
    /// [`SparseGp::from_dense`]).
    pub fn with_hp_config(mut self, config: crate::model::HpOptConfig) -> Self {
        match &mut self.inner {
            AdaptiveInner::Dense(g) => g.hp_opt.config = config,
            AdaptiveInner::Sparse(s) => s.hp_opt.config = config,
        }
        self
    }

    /// The switch-over threshold.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// Has the model migrated to the sparse representation?
    pub fn is_sparse(&self) -> bool {
        matches!(self.inner, AdaptiveInner::Sparse(_))
    }

    /// Borrow the sparse model, if migrated.
    pub fn as_sparse(&self) -> Option<&SparseGp<K, M>> {
        match &self.inner {
            AdaptiveInner::Sparse(s) => Some(s),
            AdaptiveInner::Dense(_) => None,
        }
    }

    /// Borrow the dense model, if not yet migrated.
    pub fn as_dense(&self) -> Option<&Gp<K, M>> {
        match &self.inner {
            AdaptiveInner::Dense(g) => Some(g),
            AdaptiveInner::Sparse(_) => None,
        }
    }

    /// Restore a captured state, switching representation if the capture
    /// happened on the other side of the dense→sparse migration: a
    /// freshly built adaptive model starts dense, so restoring a sparse
    /// checkpoint first migrates the (empty) dense model to carry the
    /// kernel/mean/config across, then applies the sparse state.
    fn restore_adaptive(&mut self, state: &ModelState) -> Result<(), String> {
        if matches!(state, ModelState::Sparse(_)) && !self.is_sparse() {
            let sparse = match &self.inner {
                AdaptiveInner::Dense(gp) => SparseGp::from_dense(gp, self.config.clone()),
                AdaptiveInner::Sparse(_) => unreachable!(),
            };
            self.inner = AdaptiveInner::Sparse(sparse);
        }
        match (&mut self.inner, state) {
            (AdaptiveInner::Dense(gp), ModelState::Dense(s)) => s.restore(gp),
            (AdaptiveInner::Sparse(sgp), ModelState::Sparse(s)) => s.restore(sgp),
            (AdaptiveInner::Sparse(_), ModelState::Dense(_)) => {
                Err("cannot restore dense state into a migrated sparse model".into())
            }
            (AdaptiveInner::Dense(_), ModelState::Sparse(_)) => unreachable!(),
        }
    }

    fn migrate_if_due(&mut self) {
        let replacement = match &self.inner {
            AdaptiveInner::Dense(gp) if gp.n_samples() > self.threshold => {
                Some(SparseGp::from_dense(gp, self.config.clone()))
            }
            _ => None,
        };
        if let Some(sgp) = replacement {
            self.inner = AdaptiveInner::Sparse(sgp);
        }
    }
}

impl<K: Kernel, M: MeanFn> Model for AdaptiveModel<K, M> {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) {
        match &mut self.inner {
            AdaptiveInner::Dense(gp) => gp.fit(xs, ys),
            AdaptiveInner::Sparse(sgp) => sgp.fit(xs, ys),
        }
        self.migrate_if_due();
    }

    fn add_sample(&mut self, x: &[f64], y: f64) {
        match &mut self.inner {
            AdaptiveInner::Dense(gp) => gp.add_sample(x, y),
            AdaptiveInner::Sparse(sgp) => sgp.add_sample(x, y),
        }
        self.migrate_if_due();
    }

    fn add_sample_noisy(&mut self, x: &[f64], y: f64, extra_var: f64) {
        match &mut self.inner {
            AdaptiveInner::Dense(gp) => gp.add_sample_noisy(x, y, extra_var),
            AdaptiveInner::Sparse(sgp) => sgp.add_sample_noisy(x, y, extra_var),
        }
        self.migrate_if_due();
    }

    fn has_noisy_observations(&self) -> bool {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.has_noisy_observations(),
            AdaptiveInner::Sparse(sgp) => sgp.has_noisy_observations(),
        }
    }

    fn best_predicted_mean(&self) -> Option<f64> {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.best_predicted_mean(),
            AdaptiveInner::Sparse(sgp) => sgp.best_predicted_mean(),
        }
    }

    fn predict(&self, x: &[f64]) -> (f64, f64) {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.predict(x),
            AdaptiveInner::Sparse(sgp) => sgp.predict(x),
        }
    }

    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.predict_batch(xs),
            AdaptiveInner::Sparse(sgp) => sgp.predict_batch(xs),
        }
    }

    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, crate::la::Matrix) {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.predict_joint(xs),
            AdaptiveInner::Sparse(sgp) => sgp.predict_joint(xs),
        }
    }

    fn n_samples(&self) -> usize {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.n_samples(),
            AdaptiveInner::Sparse(sgp) => sgp.n_samples(),
        }
    }

    fn dim(&self) -> usize {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.dim(),
            AdaptiveInner::Sparse(sgp) => sgp.dim(),
        }
    }

    fn best_observation(&self) -> Option<f64> {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.best_observation(),
            AdaptiveInner::Sparse(sgp) => sgp.best_observation(),
        }
    }

    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.best_sample(),
            AdaptiveInner::Sparse(sgp) => sgp.best_sample(),
        }
    }

    fn optimize_hyperparams(&mut self) {
        match &mut self.inner {
            AdaptiveInner::Dense(gp) => gp.optimize_hyperparams(),
            AdaptiveInner::Sparse(sgp) => sgp.optimize_hyperparams(),
        }
    }
}

impl<K: Kernel, M: MeanFn> StateModel for AdaptiveModel<K, M> {
    fn capture_state(&self) -> ModelState {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.capture_state(),
            AdaptiveInner::Sparse(sgp) => sgp.capture_state(),
        }
    }

    fn restore_state(&mut self, state: &ModelState) -> Result<(), String> {
        self.restore_adaptive(state)
    }

    fn hp_refits(&self) -> u64 {
        match &self.inner {
            AdaptiveInner::Dense(gp) => gp.hp_opt.refits(),
            AdaptiveInner::Sparse(sgp) => sgp.hp_opt.refits(),
        }
    }

    fn set_hp_refits(&mut self, refits: u64) {
        match &mut self.inner {
            AdaptiveInner::Dense(gp) => gp.hp_opt.set_refits(refits),
            AdaptiveInner::Sparse(sgp) => sgp.hp_opt.set_refits(refits),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::rng::Pcg64;

    #[test]
    fn migrates_past_threshold_and_stays_consistent() {
        let mut model = AdaptiveModel::new(Matern52::new(2), DataMean::default(), 0.01)
            .with_threshold(30)
            .with_sparse_config(SgpConfig { max_inducing: 32, ..SgpConfig::default() });
        let mut rng = Pcg64::seed(21);
        let f = |x: &[f64]| (3.0 * x[0]).sin() + x[1];
        let mut last_dense_pred = None;
        for i in 0..40 {
            let x = rng.unit_point(2);
            model.add_sample(&x, f(&x));
            if i == 29 {
                assert!(!model.is_sparse(), "still dense at the threshold");
                last_dense_pred = Some(model.predict(&[0.4, 0.6]));
            }
        }
        assert!(model.is_sparse(), "migrated past the threshold");
        assert_eq!(model.n_samples(), 40);
        assert!(model.best_observation().is_some());
        // the sparse posterior stays close to the last dense one
        let (md, _) = last_dense_pred.unwrap();
        let (ms, vs) = model.predict(&[0.4, 0.6]);
        assert!(vs > 0.0 && vs.is_finite());
        assert!((md - ms).abs() < 0.3, "dense {md} vs sparse {ms}");
    }

    #[test]
    fn fit_chooses_representation_by_size() {
        let mut rng = Pcg64::seed(3);
        let xs: Vec<Vec<f64>> = (0..20).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0]).collect();
        let mut model =
            AdaptiveModel::new(Matern52::new(1), DataMean::default(), 0.01).with_threshold(10);
        model.fit(&xs, &ys);
        assert!(model.is_sparse());

        let mut small =
            AdaptiveModel::new(Matern52::new(1), DataMean::default(), 0.01).with_threshold(100);
        small.fit(&xs, &ys);
        assert!(!small.is_sparse());
        assert!(small.as_dense().is_some());
    }
}
