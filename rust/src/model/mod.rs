//! Surrogate models — the `limbo::model::*` policy family.
//!
//! [`Model`] is the interface the acquisition functions and the
//! [`crate::bayes_opt::BOptimizer`] loop see; [`gp::Gp`] is the native
//! (pure-Rust, incremental-Cholesky) implementation,
//! [`sgp::SparseGp`] the inducing-point approximation for large budgets
//! (with [`sgp::AdaptiveModel`] switching between the two), and
//! [`crate::runtime::XlaGp`] backs the same interface with AOT-compiled
//! XLA artifacts (adapter in [`crate::coordinator`]).

pub mod bank;
pub mod gp;
pub mod hp_opt;
pub mod serde;
pub mod sgp;

pub use bank::ModelBank;
pub use gp::Gp;
pub use serde::{BankState, GpState, ModelState, SgpState, StateModel};
pub use hp_opt::{HpOptConfig, KernelLFOpt, LmlModel};
pub use sgp::{AdaptiveModel, SgpConfig, SparseGp};

use crate::la::Matrix;

/// Finite-filtering argmax scan over stored samples — the shared body of
/// the sample-retaining models' [`Model::best_sample`] implementations
/// (non-finite observations never become the incumbent).
pub(crate) fn best_sample_of(xs: &[Vec<f64>], ys: &[f64]) -> Option<(Vec<f64>, f64)> {
    let (mut arg, mut best) = (None, f64::NEG_INFINITY);
    for (x, &y) in xs.iter().zip(ys) {
        if y.is_finite() && (arg.is_none() || y > best) {
            arg = Some(x);
            best = y;
        }
    }
    arg.map(|x| (x.clone(), best))
}

/// A probabilistic surrogate: fit observations, predict mean + variance.
pub trait Model: Send + Sync {
    /// Full refit from scratch.
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]);

    /// Add one observation (implementations may do an incremental update).
    fn add_sample(&mut self, x: &[f64], y: f64);

    /// Add one observation with `extra_var` of *additional* observation
    /// noise variance on top of the model's homoskedastic `sigma_n^2` —
    /// the heteroskedastic intake behind
    /// [`tell_noisy`](crate::coordinator::Study::tell_noisy). The extra
    /// variance widens the training diagonal for this row only, so a
    /// known-noisy measurement pulls the posterior less than an exact
    /// one. `extra_var <= 0.0` must be *exactly* equivalent to
    /// [`add_sample`](Self::add_sample) (the degenerate-case parity the
    /// API tests pin bit-for-bit). Default: ignore the extra variance.
    fn add_sample_noisy(&mut self, x: &[f64], y: f64, extra_var: f64) {
        let _ = extra_var;
        self.add_sample(x, y);
    }

    /// Whether any fitted observation carries extra per-observation noise
    /// ([`add_sample_noisy`](Self::add_sample_noisy) with a positive
    /// variance). The improvement-based acquisitions switch their
    /// incumbent from best raw observation to best *predicted mean* when
    /// this is true — a single lucky noisy draw must not pin the EI/PI
    /// threshold. Default `false` for noise-unaware models.
    fn has_noisy_observations(&self) -> bool {
        false
    }

    /// Best (max) posterior mean over the *training* inputs — the
    /// incumbent under observation noise. `None` if the model has no data
    /// or does not retain its training inputs. Default `None`.
    fn best_predicted_mean(&self) -> Option<f64> {
        None
    }

    /// Number of constraint channels this model carries surrogates for.
    /// `0` for plain single-output models; [`bank::ModelBank`] reports
    /// its constraint-surrogate count.
    fn n_constraint_channels(&self) -> usize {
        0
    }

    /// Feed one constraint observation vector (one value per channel,
    /// same `x` as the paired objective sample) into the constraint
    /// surrogates. No-op for models without constraint channels; the
    /// caller validates arity against
    /// [`n_constraint_channels`](Self::n_constraint_channels).
    fn add_constraint_sample(&mut self, x: &[f64], cs: &[f64]) {
        let _ = (x, cs);
    }

    /// Posterior `(mean, variance)` of the latent function at `x`.
    fn predict(&self, x: &[f64]) -> (f64, f64);

    /// Posterior `(mean, variance)` for a whole candidate batch.
    ///
    /// This is the hot entry point of the acquisition-maximization loop:
    /// population-based inner optimizers route entire candidate
    /// generations through it (via `Objective::eval_many` →
    /// `AcquiFn::eval_batch`). The default loops over
    /// [`predict`](Self::predict); real implementations amortize the
    /// per-candidate work — [`gp::Gp`] builds one cross-covariance Gram
    /// block and runs one multi-RHS triangular solve, [`sgp::SparseGp`]
    /// solves a single `m x B` feature block, and the XLA adapter
    /// delegates to its fused batched artifact.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Joint posterior over a candidate batch: the mean vector and the
    /// full `B x B` posterior covariance of the latent function at `xs`.
    ///
    /// This is the entry point of the joint batch acquisitions
    /// ([`crate::acqui::batch`]): Monte-Carlo qEI draws correlated sample
    /// paths `mu + L z` from this covariance, so batch proposals account
    /// for the correlation between candidate points instead of scoring
    /// them independently. The covariance diagonal must match
    /// [`predict_batch`](Self::predict_batch) variances (clamped at the
    /// same `1e-12` floor); implementations assemble the dense `B x B`
    /// block from one cross-covariance block and one multi-RHS solve.
    ///
    /// The default is the *uncorrelated* fallback — a diagonal covariance
    /// from `predict_batch` — for backends without joint-posterior
    /// support (e.g. the XLA artifact adapter); qEI degenerates to
    /// independent draws there but stays well-defined.
    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, Matrix) {
        let preds = self.predict_batch(xs);
        let b = preds.len();
        let mut cov = Matrix::zeros(b, b);
        let mut mus = Vec::with_capacity(b);
        for (j, (mu, var)) in preds.into_iter().enumerate() {
            mus.push(mu);
            cov[(j, j)] = var;
        }
        (mus, cov)
    }

    /// Number of fitted observations.
    fn n_samples(&self) -> usize;

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Best (max) observed value so far, if any.
    fn best_observation(&self) -> Option<f64>;

    /// Best observed `(x, y)` pair, if the model can recover the argmax
    /// from its stored samples. Lets a freshly constructed
    /// [`crate::coordinator::AskTellServer`] seed its incumbent from a
    /// model that already has data (`fit` / deserialized state) instead
    /// of lying `None` until the first `tell`. Default `None` for models
    /// that do not retain their training inputs.
    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        None
    }

    /// Re-optimize hyper-parameters from the current data (ML-II).
    /// Default: no-op (not every model has hyper-parameters).
    fn optimize_hyperparams(&mut self) {}
}
