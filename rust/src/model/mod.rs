//! Surrogate models — the `limbo::model::*` policy family.
//!
//! [`Model`] is the interface the acquisition functions and the
//! [`crate::bayes_opt::BOptimizer`] loop see; [`gp::Gp`] is the native
//! (pure-Rust, incremental-Cholesky) implementation,
//! [`sgp::SparseGp`] the inducing-point approximation for large budgets
//! (with [`sgp::AdaptiveModel`] switching between the two), and
//! [`crate::runtime::XlaGp`] backs the same interface with AOT-compiled
//! XLA artifacts (adapter in [`crate::coordinator`]).

pub mod gp;
pub mod hp_opt;
pub mod serde;
pub mod sgp;

pub use gp::Gp;
pub use serde::{GpState, ModelState, SgpState, StateModel};
pub use hp_opt::{HpOptConfig, KernelLFOpt, LmlModel};
pub use sgp::{AdaptiveModel, SgpConfig, SparseGp};

use crate::la::Matrix;

/// Finite-filtering argmax scan over stored samples — the shared body of
/// the sample-retaining models' [`Model::best_sample`] implementations
/// (non-finite observations never become the incumbent).
pub(crate) fn best_sample_of(xs: &[Vec<f64>], ys: &[f64]) -> Option<(Vec<f64>, f64)> {
    let (mut arg, mut best) = (None, f64::NEG_INFINITY);
    for (x, &y) in xs.iter().zip(ys) {
        if y.is_finite() && (arg.is_none() || y > best) {
            arg = Some(x);
            best = y;
        }
    }
    arg.map(|x| (x.clone(), best))
}

/// A probabilistic surrogate: fit observations, predict mean + variance.
pub trait Model: Send + Sync {
    /// Full refit from scratch.
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]);

    /// Add one observation (implementations may do an incremental update).
    fn add_sample(&mut self, x: &[f64], y: f64);

    /// Posterior `(mean, variance)` of the latent function at `x`.
    fn predict(&self, x: &[f64]) -> (f64, f64);

    /// Posterior `(mean, variance)` for a whole candidate batch.
    ///
    /// This is the hot entry point of the acquisition-maximization loop:
    /// population-based inner optimizers route entire candidate
    /// generations through it (via `Objective::eval_many` →
    /// `AcquiFn::eval_batch`). The default loops over
    /// [`predict`](Self::predict); real implementations amortize the
    /// per-candidate work — [`gp::Gp`] builds one cross-covariance Gram
    /// block and runs one multi-RHS triangular solve, [`sgp::SparseGp`]
    /// solves a single `m x B` feature block, and the XLA adapter
    /// delegates to its fused batched artifact.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Joint posterior over a candidate batch: the mean vector and the
    /// full `B x B` posterior covariance of the latent function at `xs`.
    ///
    /// This is the entry point of the joint batch acquisitions
    /// ([`crate::acqui::batch`]): Monte-Carlo qEI draws correlated sample
    /// paths `mu + L z` from this covariance, so batch proposals account
    /// for the correlation between candidate points instead of scoring
    /// them independently. The covariance diagonal must match
    /// [`predict_batch`](Self::predict_batch) variances (clamped at the
    /// same `1e-12` floor); implementations assemble the dense `B x B`
    /// block from one cross-covariance block and one multi-RHS solve.
    ///
    /// The default is the *uncorrelated* fallback — a diagonal covariance
    /// from `predict_batch` — for backends without joint-posterior
    /// support (e.g. the XLA artifact adapter); qEI degenerates to
    /// independent draws there but stays well-defined.
    fn predict_joint(&self, xs: &[Vec<f64>]) -> (Vec<f64>, Matrix) {
        let preds = self.predict_batch(xs);
        let b = preds.len();
        let mut cov = Matrix::zeros(b, b);
        let mut mus = Vec::with_capacity(b);
        for (j, (mu, var)) in preds.into_iter().enumerate() {
            mus.push(mu);
            cov[(j, j)] = var;
        }
        (mus, cov)
    }

    /// Number of fitted observations.
    fn n_samples(&self) -> usize;

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Best (max) observed value so far, if any.
    fn best_observation(&self) -> Option<f64>;

    /// Best observed `(x, y)` pair, if the model can recover the argmax
    /// from its stored samples. Lets a freshly constructed
    /// [`crate::coordinator::AskTellServer`] seed its incumbent from a
    /// model that already has data (`fit` / deserialized state) instead
    /// of lying `None` until the first `tell`. Default `None` for models
    /// that do not retain their training inputs.
    fn best_sample(&self) -> Option<(Vec<f64>, f64)> {
        None
    }

    /// Re-optimize hyper-parameters from the current data (ML-II).
    /// Default: no-op (not every model has hyper-parameters).
    fn optimize_hyperparams(&mut self) {}
}
