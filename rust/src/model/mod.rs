//! Surrogate models — the `limbo::model::*` policy family.
//!
//! [`Model`] is the interface the acquisition functions and the
//! [`crate::bayes_opt::BOptimizer`] loop see; [`gp::Gp`] is the native
//! (pure-Rust, incremental-Cholesky) implementation,
//! [`sgp::SparseGp`] the inducing-point approximation for large budgets
//! (with [`sgp::AdaptiveModel`] switching between the two), and
//! [`crate::runtime::XlaGp`] backs the same interface with AOT-compiled
//! XLA artifacts (adapter in [`crate::coordinator`]).

pub mod gp;
pub mod hp_opt;
pub mod serde;
pub mod sgp;

pub use gp::Gp;
pub use serde::{GpState, SgpState};
pub use hp_opt::{HpOptConfig, KernelLFOpt, LmlModel};
pub use sgp::{AdaptiveModel, SgpConfig, SparseGp};

/// A probabilistic surrogate: fit observations, predict mean + variance.
pub trait Model: Send + Sync {
    /// Full refit from scratch.
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]);

    /// Add one observation (implementations may do an incremental update).
    fn add_sample(&mut self, x: &[f64], y: f64);

    /// Posterior `(mean, variance)` of the latent function at `x`.
    fn predict(&self, x: &[f64]) -> (f64, f64);

    /// Posterior `(mean, variance)` for a whole candidate batch.
    ///
    /// This is the hot entry point of the acquisition-maximization loop:
    /// population-based inner optimizers route entire candidate
    /// generations through it (via `Objective::eval_many` →
    /// `AcquiFn::eval_batch`). The default loops over
    /// [`predict`](Self::predict); real implementations amortize the
    /// per-candidate work — [`gp::Gp`] builds one cross-covariance Gram
    /// block and runs one multi-RHS triangular solve, [`sgp::SparseGp`]
    /// solves a single `m x B` feature block, and the XLA adapter
    /// delegates to its fused batched artifact.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Number of fitted observations.
    fn n_samples(&self) -> usize;

    /// Input dimensionality.
    fn dim(&self) -> usize;

    /// Best (max) observed value so far, if any.
    fn best_observation(&self) -> Option<f64>;

    /// Re-optimize hyper-parameters from the current data (ML-II).
    /// Default: no-op (not every model has hyper-parameters).
    fn optimize_hyperparams(&mut self) {}
}
