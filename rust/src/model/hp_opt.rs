//! ML-II hyper-parameter optimization (Limbo's `model::gp::KernelLFOpt`):
//! maximize the log marginal likelihood over the kernel's log-hyper-params
//! (+ optionally log-noise) with iRprop⁻ restarts.
//!
//! Rprop is what Limbo itself uses: it only needs gradient *signs*, is
//! robust to the wildly different curvature of lengthscale vs variance
//! axes, and needs no line search.

use crate::kernel::Kernel;
use crate::mean::MeanFn;
use crate::model::gp::Gp;
use crate::model::Model;
use crate::opt::rprop::{rprop_maximize, RpropParams};
use crate::rng::Pcg64;

/// Settings for the likelihood fit.
#[derive(Clone, Debug)]
pub struct HpOptConfig {
    /// Rprop iterations per restart.
    pub iterations: usize,
    /// Number of random restarts (first start = current params).
    pub restarts: usize,
    /// Uniform width of restart perturbations in log space.
    pub perturbation: f64,
    /// Clamp on |log param| to keep the Gram matrix sane.
    pub bound: f64,
    /// RNG seed for restart draws (deterministic fits).
    pub seed: u64,
}

impl Default for HpOptConfig {
    fn default() -> Self {
        Self { iterations: 50, restarts: 3, perturbation: 2.0, bound: 6.0, seed: 0x4C4D4C }
    }
}

/// The likelihood optimizer object stored inside [`Gp`].
#[derive(Clone, Debug, Default)]
pub struct KernelLFOpt {
    /// Tunable settings.
    pub config: HpOptConfig,
}

impl KernelLFOpt {
    /// Maximize the GP's LML in place. Keeps the best of all restarts;
    /// never leaves the GP worse than it started.
    pub fn run<K: Kernel, M: MeanFn>(&self, gp: &mut Gp<K, M>) {
        let cfg = &self.config;
        let start = gp.hp_vector();
        let nprm = start.len();
        let mut rng = Pcg64::seed(cfg.seed ^ gp.n_samples() as u64);

        let mut best_p = start.clone();
        let mut best_lml = gp.log_marginal_likelihood();

        for restart in 0..cfg.restarts.max(1) {
            let x0: Vec<f64> = if restart == 0 {
                start.clone()
            } else {
                start
                    .iter()
                    .map(|&v| {
                        (v + rng.uniform(-cfg.perturbation, cfg.perturbation))
                            .clamp(-cfg.bound, cfg.bound)
                    })
                    .collect()
            };
            let params = RpropParams { iterations: cfg.iterations, ..RpropParams::default() };
            let bound = cfg.bound;
            let p = rprop_maximize(
                |p| {
                    gp.set_hp_vector(p);
                    (gp.log_marginal_likelihood(), gp.lml_grad())
                },
                &x0,
                &params,
                Some((-bound, bound)),
            );
            gp.set_hp_vector(&p);
            let lml = gp.log_marginal_likelihood();
            if lml > best_lml && lml.is_finite() {
                best_lml = lml;
                best_p = p;
            }
            let _ = nprm;
        }
        gp.set_hp_vector(&best_p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Kernel, SquaredExpArd};
    use crate::mean::ZeroMean;
    use crate::model::Model;
    use crate::rng::Pcg64;

    #[test]
    fn hp_opt_improves_lml() {
        let mut rng = Pcg64::seed(2024);
        // data drawn from a short-lengthscale function; start the GP with
        // a badly mis-specified lengthscale
        let xs: Vec<Vec<f64>> = (0..25).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (12.0 * x[0]).sin()).collect();
        let mut gp = Gp::new(SquaredExpArd::with_params(vec![2.0], 0.0), ZeroMean, 0.05);
        gp.fit(&xs, &ys);
        let before = gp.log_marginal_likelihood();
        gp.optimize_hyperparams();
        let after = gp.log_marginal_likelihood();
        assert!(after > before + 1.0, "LML should improve: {before} -> {after}");
        // the fitted lengthscale should have shrunk towards the true scale
        let fitted_l = gp.kernel().params()[0].exp();
        assert!(fitted_l < 1.0, "fitted lengthscale {fitted_l} should be < start 7.4");
    }

    #[test]
    fn hp_opt_never_degrades() {
        let mut rng = Pcg64::seed(77);
        let xs: Vec<Vec<f64>> = (0..8).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] + x[1]).collect();
        let mut gp = Gp::new(SquaredExpArd::new(2), ZeroMean, 0.1);
        gp.fit(&xs, &ys);
        let before = gp.log_marginal_likelihood();
        gp.optimize_hyperparams();
        assert!(gp.log_marginal_likelihood() >= before - 1e-9);
    }

    #[test]
    fn noop_on_tiny_datasets() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.1);
        gp.add_sample(&[0.5], 1.0);
        let p = gp.hp_vector();
        gp.optimize_hyperparams();
        assert_eq!(gp.hp_vector(), p);
    }
}
