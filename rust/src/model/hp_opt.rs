//! ML-II hyper-parameter optimization (Limbo's `model::gp::KernelLFOpt`):
//! maximize the log marginal likelihood over the model's log-hyper-params
//! (+ optionally log-noise) with iRprop⁻ restarts.
//!
//! Rprop is what Limbo itself uses: it only needs gradient *signs*, is
//! robust to the wildly different curvature of lengthscale vs variance
//! axes, and needs no line search.
//!
//! The optimizer is generic over [`LmlModel`] — any surrogate exposing an
//! exact `(lml, lml_grad)` pair. The dense [`Gp`](crate::model::gp::Gp)
//! fits its O(n³) marginal likelihood; the sparse
//! [`SparseGp`](crate::model::sgp::SparseGp) fits the exact FITC marginal
//! likelihood in O(n·m²) per step (no dense-subset proxy). Restarts fan
//! out over [`crate::pool::parallel_map_catch`], each on its own clone of
//! the model, so a panicking restart costs only that restart.

use crate::obs::{self, Counter, Phase};
use crate::opt::rprop::{rprop_maximize, RpropParams};
use crate::pool::parallel_map_catch;
use crate::rng::Pcg64;

/// A surrogate whose log marginal likelihood and analytic gradient are
/// available for ML-II fitting. The hyper vector convention is
/// `[kernel log-params..., log sigma_n]` throughout.
pub trait LmlModel: Clone + Send + Sync {
    /// Current log-hyper vector.
    fn hp_vector(&self) -> Vec<f64>;

    /// Apply a log-hyper vector and refit whatever factors the marginal
    /// likelihood depends on.
    fn apply_hp_vector(&mut self, p: &[f64]);

    /// Log marginal likelihood of the current fit.
    fn lml(&self) -> f64;

    /// Gradient of the LML w.r.t. the hyper vector.
    fn lml_grad(&self) -> Vec<f64>;

    /// Number of fitted observations (mixed into the restart seed).
    fn n_samples(&self) -> usize;
}

/// Settings for the likelihood fit.
#[derive(Clone, Debug)]
pub struct HpOptConfig {
    /// Rprop iterations per restart.
    pub iterations: usize,
    /// Number of random restarts (first start = current params).
    pub restarts: usize,
    /// Uniform width of restart perturbations in log space.
    pub perturbation: f64,
    /// Clamp on |log param| to keep the Gram matrix sane.
    pub bound: f64,
    /// RNG seed for restart draws (deterministic fits).
    pub seed: u64,
    /// Worker threads for the restart fan-out (0 = one per restart).
    pub threads: usize,
}

impl Default for HpOptConfig {
    fn default() -> Self {
        Self {
            iterations: 50,
            restarts: 3,
            perturbation: 2.0,
            bound: 6.0,
            seed: 0x4C4D4C,
            threads: 0,
        }
    }
}

/// splitmix64-style avalanche so nearby inputs land on unrelated streams.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Restart-stream seed for refit number `refit` on an `n`-sample dataset.
///
/// The old scheme (`seed ^ n`) replayed identical perturbations whenever
/// a service refit ran on an equal-sized dataset — every refit explored
/// the same (possibly unlucky) starting points. Mixing the refit counter
/// through an avalanche makes every `(n, refit)` pair an independent
/// stream.
pub(crate) fn restart_seed(seed: u64, n: u64, refit: u64) -> u64 {
    splitmix(seed ^ splitmix(n ^ splitmix(refit)))
}

/// The likelihood optimizer stored inside [`Gp`](crate::model::gp::Gp)
/// and [`SparseGp`](crate::model::sgp::SparseGp).
#[derive(Clone, Debug, Default)]
pub struct KernelLFOpt {
    /// Tunable settings.
    pub config: HpOptConfig,
    /// Completed [`run`](Self::run) calls, mixed into the restart seed so
    /// repeated refits on equal-sized datasets draw fresh perturbations.
    refits: u64,
}

impl KernelLFOpt {
    /// Number of completed fits (the refit counter mixed into the seed).
    pub fn refits(&self) -> u64 {
        self.refits
    }

    /// Restore the refit counter from a checkpoint. The counter feeds
    /// [`restart_seed`], so a rehydrated study must carry it over for its
    /// next refit to draw the same perturbations the uninterrupted run
    /// would have drawn.
    pub fn set_refits(&mut self, refits: u64) {
        self.refits = refits;
    }

    /// Maximize the model's LML in place. Restarts run in parallel on
    /// clones of the model (each a full rprop trajectory); the best of
    /// all restarts — never worse than the starting point — is applied.
    pub fn run<T: LmlModel>(&mut self, model: &mut T) {
        let _span = obs::span(Phase::HpOpt);
        obs::counter_add(Counter::HpRestarts, self.config.restarts.max(1) as u64);
        let start = model.hp_vector();
        let seed = restart_seed(self.config.seed, model.n_samples() as u64, self.refits);
        self.refits += 1;
        let cfg = &self.config;
        let mut rng = Pcg64::seed(seed);

        let x0s: Vec<Vec<f64>> = (0..cfg.restarts.max(1))
            .map(|restart| {
                if restart == 0 {
                    start.clone()
                } else {
                    start
                        .iter()
                        .map(|&v| {
                            (v + rng.uniform(-cfg.perturbation, cfg.perturbation))
                                .clamp(-cfg.bound, cfg.bound)
                        })
                        .collect()
                }
            })
            .collect();

        let params = RpropParams { iterations: cfg.iterations, ..RpropParams::default() };
        let bound = cfg.bound;
        let threads = if cfg.threads == 0 { x0s.len() } else { cfg.threads };
        let base = &*model;
        let results = parallel_map_catch(x0s, threads, |_, x0| {
            let mut scratch = base.clone();
            let p = rprop_maximize(
                |p| {
                    scratch.apply_hp_vector(p);
                    (scratch.lml(), scratch.lml_grad())
                },
                &x0,
                &params,
                Some((-bound, bound)),
            );
            scratch.apply_hp_vector(&p);
            (p, scratch.lml())
        });

        let mut best_p = start;
        let mut best_lml = model.lml();
        for (p, lml) in results.into_iter().flatten() {
            if lml.is_finite() && lml > best_lml {
                best_lml = lml;
                best_p = p;
            }
        }
        model.apply_hp_vector(&best_p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{Kernel, SquaredExpArd};
    use crate::mean::ZeroMean;
    use crate::model::gp::Gp;
    use crate::model::Model;
    use crate::rng::Pcg64;

    #[test]
    fn hp_opt_improves_lml() {
        let mut rng = Pcg64::seed(2024);
        // data drawn from a short-lengthscale function; start the GP with
        // a badly mis-specified lengthscale
        let xs: Vec<Vec<f64>> = (0..25).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (12.0 * x[0]).sin()).collect();
        let mut gp = Gp::new(SquaredExpArd::with_params(vec![2.0], 0.0), ZeroMean, 0.05);
        gp.fit(&xs, &ys);
        let before = gp.log_marginal_likelihood();
        gp.optimize_hyperparams();
        let after = gp.log_marginal_likelihood();
        assert!(after > before + 1.0, "LML should improve: {before} -> {after}");
        // the fitted lengthscale should have shrunk towards the true scale
        let fitted_l = gp.kernel().params()[0].exp();
        assert!(fitted_l < 1.0, "fitted lengthscale {fitted_l} should be < start 7.4");
    }

    #[test]
    fn hp_opt_never_degrades() {
        let mut rng = Pcg64::seed(77);
        let xs: Vec<Vec<f64>> = (0..8).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0] + x[1]).collect();
        let mut gp = Gp::new(SquaredExpArd::new(2), ZeroMean, 0.1);
        gp.fit(&xs, &ys);
        let before = gp.log_marginal_likelihood();
        gp.optimize_hyperparams();
        assert!(gp.log_marginal_likelihood() >= before - 1e-9);
    }

    #[test]
    fn noop_on_tiny_datasets() {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.1);
        gp.add_sample(&[0.5], 1.0);
        let p = gp.hp_vector();
        gp.optimize_hyperparams();
        assert_eq!(gp.hp_vector(), p);
    }

    #[test]
    fn refit_counter_advances_and_survives_optimize() {
        let mut rng = Pcg64::seed(5);
        let xs: Vec<Vec<f64>> = (0..10).map(|_| rng.unit_point(1)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[0]).collect();
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.1);
        gp.hp_opt.config.iterations = 2;
        gp.hp_opt.config.restarts = 2;
        gp.fit(&xs, &ys);
        assert_eq!(gp.hp_opt.refits(), 0);
        gp.optimize_hyperparams();
        gp.optimize_hyperparams();
        // the counter must persist across calls (it de-correlates restart
        // draws of successive service refits on equal-sized datasets)
        assert_eq!(gp.hp_opt.refits(), 2);
    }

    #[test]
    fn restart_seed_mixes_refits_and_sizes() {
        // regression: `seed ^ n` collided for equal-sized datasets across
        // refits, replaying identical restart perturbations
        let s = 0x4C4D4C;
        assert_ne!(restart_seed(s, 100, 0), restart_seed(s, 100, 1));
        assert_ne!(restart_seed(s, 100, 1), restart_seed(s, 100, 2));
        assert_ne!(restart_seed(s, 100, 0), restart_seed(s, 101, 0));
        // the old scheme's xor-cancellation pairs must not collide either
        assert_ne!(restart_seed(s, 3, 0), restart_seed(s ^ 3, 0, 0));
    }
}
