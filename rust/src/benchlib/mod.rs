//! Tiny criterion-like benchmark harness + summary statistics.
//!
//! criterion is not available offline, so `cargo bench` targets use this:
//! `harness = false` benches call [`Bencher::bench`] which warms up, picks
//! an iteration count for a target sample time, collects wall-clock
//! samples, and prints a stable `name  median  p10  p90  mean` row.
//! The same [`Summary`] quantile machinery backs the Figure-1 experiment
//! tables (median / quartiles / whiskers, matching the paper's box plots).

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Quantile summary of a sample set (the paper's box-plot statistics).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    pub min: f64,
    pub p10: f64,
    /// First quartile.
    pub q1: f64,
    pub median: f64,
    /// Third quartile.
    pub q3: f64,
    pub p90: f64,
    pub max: f64,
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
}

impl Summary {
    /// Compute from raw samples (empty input yields NaNs with n = 0).
    pub fn from(samples: &[f64]) -> Self {
        let n = samples.len();
        if n == 0 {
            let nan = f64::NAN;
            return Self { n, min: nan, p10: nan, q1: nan, median: nan, q3: nan, p90: nan, max: nan, mean: nan, std: nan };
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            s.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            min: s[0],
            p10: quantile(&s, 0.10),
            q1: quantile(&s, 0.25),
            median: quantile(&s, 0.50),
            q3: quantile(&s, 0.75),
            p90: quantile(&s, 0.90),
            max: s[n - 1],
            mean,
            std: var.sqrt(),
        }
    }
}

/// Linear-interpolation quantile of a **sorted** slice (type-7, the
/// numpy default).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Bench driver: collects `samples` timing samples of `iters` iterations.
pub struct Bencher {
    /// Warm-up duration before measuring.
    pub warmup: Duration,
    /// Target time a single sample should take (sets iters/sample).
    pub sample_time: Duration,
    /// Number of samples to collect.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            sample_time: Duration::from_millis(50),
            samples: 20,
        }
    }
}

/// Result of one benchmark: per-iteration seconds summary.
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration time statistics, in seconds.
    pub per_iter: Summary,
    /// Iterations per sample used.
    pub iters: usize,
}

impl BenchResult {
    /// One formatted row: name, median, p10, p90, mean (auto-scaled unit).
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            self.name,
            fmt_time(self.per_iter.median),
            fmt_time(self.per_iter.p10),
            fmt_time(self.per_iter.p90),
            fmt_time(self.per_iter.mean),
        )
    }
}

/// Format seconds with an auto-picked unit.
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".to_string();
    }
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            sample_time: Duration::from_millis(20),
            samples: 10,
        }
    }

    /// Run `f` repeatedly; returns per-iteration timing stats.
    pub fn bench<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        // warm-up & calibration
        let start = Instant::now();
        let mut calib_iters = 0usize;
        while start.elapsed() < self.warmup {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.sample_time.as_secs_f64() / per_iter).ceil() as usize).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let res = BenchResult { name: name.to_string(), per_iter: Summary::from(&samples), iters };
        println!("{}", res.row());
        res
    }
}

/// Print the standard bench table header.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>12}",
        "benchmark", "median", "p10", "p90", "mean"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_match_numpy_type7() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&s, 0.0), 1.0);
        assert_eq!(quantile(&s, 1.0), 4.0);
        assert_eq!(quantile(&s, 0.5), 2.5);
        assert!((quantile(&s, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single_and_empty() {
        let one = Summary::from(&[5.0]);
        assert_eq!(one.median, 5.0);
        assert_eq!(one.std, 0.0);
        let zero = Summary::from(&[]);
        assert_eq!(zero.n, 0);
        assert!(zero.median.is_nan());
    }

    #[test]
    fn bencher_runs() {
        let b = Bencher { warmup: Duration::from_millis(5), sample_time: Duration::from_millis(2), samples: 3 };
        let r = b.bench("noop", || 1 + 1);
        assert_eq!(r.per_iter.n, 3);
        assert!(r.per_iter.median >= 0.0);
    }
}
