//! Minimal fixed-size thread pool (the TBB/tokio replacement).
//!
//! Two entry points:
//! * [`ThreadPool`] — long-lived pool with a job queue (used by the
//!   coordinator's experiment runner and the ask/tell service),
//! * [`parallel_map`] — scoped fork-join helper used for parallel
//!   restarts of the inner optimizers (Limbo's "several restarts ...
//!   performed in parallel").

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with a shared queue.
pub struct ThreadPool {
    sender: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (>= 1 enforced).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..threads)
            .map(|_| {
                let receiver = Arc::clone(&receiver);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let msg = { receiver.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            job();
                            let (lock, cvar) = &*pending;
                            let mut n = lock.lock().unwrap();
                            *n -= 1;
                            if *n == 0 {
                                cvar.notify_all();
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        Self { sender, workers, pending }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.sender.send(Message::Run(Box::new(job))).expect("pool shut down");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cvar.wait(n).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fork-join parallel map over `items`, preserving order, using scoped
/// threads (`threads` capped by item count; `threads == 1` runs inline).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let results = Mutex::new(&mut slots);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, t)) => {
                        let r = f(i, t);
                        results.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs_exactly_once() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = parallel_map(items, 4, |_, x| x * 2);
        assert_eq!(out, (0..57).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_inline() {
        let out = parallel_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }
}
