//! Minimal fixed-size thread pool (the TBB/tokio replacement).
//!
//! Two entry points:
//! * [`ThreadPool`] — long-lived pool with a job queue (used by the
//!   coordinator's experiment runner and the ask/tell service),
//! * [`parallel_map`] — scoped fork-join helper used for parallel
//!   restarts of the inner optimizers (Limbo's "several restarts ...
//!   performed in parallel") and by the blocked `la` kernels for panel
//!   fan-out. [`parallel_map_hinted`] adds a small-input fast path: a
//!   work estimate below a threshold runs every item inline on the
//!   calling thread, so tiny matrices never pay fork/queue overhead
//!   (the `pool_queue_wait`/`pool_exec` spans price that overhead when
//!   metrics are on).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use crate::obs::{self, Counter, Phase};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Lock a mutex, recovering the guard even if another thread poisoned it
/// (a panicking job must never be able to wedge the pool's bookkeeping).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Fixed-size thread pool with a shared queue.
///
/// Panic-safe: a job that panics is caught on the worker, counted in
/// [`panicked_jobs`](Self::panicked_jobs), and the pending count is still
/// decremented — [`wait_idle`](Self::wait_idle) can never hang on a
/// poisoned pending-count mutex, and the worker survives to run the next
/// job.
pub struct ThreadPool {
    sender: mpsc::Sender<Message>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (>= 1 enforced).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (sender, receiver) = mpsc::channel::<Message>();
        let receiver = Arc::new(Mutex::new(receiver));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let receiver = Arc::clone(&receiver);
                let pending = Arc::clone(&pending);
                let panicked = Arc::clone(&panicked);
                thread::spawn(move || loop {
                    let msg = { lock_unpoisoned(&receiver).recv() };
                    match msg {
                        Ok(Message::Run(job)) => {
                            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panicked.fetch_add(1, Ordering::SeqCst);
                            }
                            let (lock, cvar) = &*pending;
                            let mut n = lock_unpoisoned(lock);
                            *n -= 1;
                            if *n == 0 {
                                cvar.notify_all();
                            }
                        }
                        Ok(Message::Shutdown) | Err(_) => break,
                    }
                })
            })
            .collect();
        Self { sender, workers, pending, panicked }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job. When metrics are enabled the job is wrapped to
    /// attribute its queue wait (submit → dequeue) and execution time to
    /// [`Phase::PoolQueueWait`] / [`Phase::PoolExec`].
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        obs::counter_add(Counter::PoolJobs, 1);
        {
            let (lock, _) = &*self.pending;
            *lock_unpoisoned(lock) += 1;
        }
        let enqueued = if obs::enabled() { Some(Instant::now()) } else { None };
        let job = move || {
            if let Some(t0) = enqueued {
                obs::record_duration(Phase::PoolQueueWait, t0.elapsed());
            }
            let _span = obs::span(Phase::PoolExec);
            job();
        };
        self.sender.send(Message::Run(Box::new(job))).expect("pool shut down");
    }

    /// Block until every submitted job has finished (including jobs that
    /// panicked — see [`panicked_jobs`](Self::panicked_jobs)).
    pub fn wait_idle(&self) {
        let (lock, cvar) = &*self.pending;
        let mut n = lock_unpoisoned(lock);
        while *n > 0 {
            n = cvar.wait(n).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Number of jobs that have panicked since the pool was created.
    pub fn panicked_jobs(&self) -> usize {
        self.panicked.load(Ordering::SeqCst)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.sender.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A caught panic payload, as `catch_unwind` hands it back.
type PanicPayload = Box<dyn std::any::Any + Send>;

/// Render a caught panic payload as a human-readable message (`panic!`
/// with a literal or formatted string covers virtually every payload).
fn panic_message(payload: PanicPayload) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked (non-string payload)".to_string()
    }
}

/// Shared fork-join core of [`parallel_map`]/[`parallel_map_catch`]:
/// every item is processed (scoped workers, or inline for one thread),
/// per-item panics are caught into the item's own result slot, and no
/// shared mutex is ever poisoned.
fn parallel_map_core<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<Result<R, PanicPayload>>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items
            .into_iter()
            .enumerate()
            .map(|(i, t)| catch_unwind(AssertUnwindSafe(|| f(i, t))))
            .collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<Result<R, PanicPayload>>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = Mutex::new(work);
    let results = Mutex::new(&mut slots);
    // fork timestamp for queue-wait attribution: time from fork to an
    // item's dequeue is exactly how long that item sat in the queue
    let forked = if obs::enabled() { Some(Instant::now()) } else { None };
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = lock_unpoisoned(&queue).pop();
                match item {
                    Some((i, t)) => {
                        if let Some(t0) = forked {
                            obs::record_duration(Phase::PoolQueueWait, t0.elapsed());
                        }
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            let _span = obs::span(Phase::PoolExec);
                            f(i, t)
                        }));
                        lock_unpoisoned(&results)[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots.into_iter().map(|s| s.expect("slot filled by worker")).collect()
}

/// Like [`parallel_map`], but a panicking item yields `Err(message)` in
/// its slot instead of re-raising after the drain — callers that own a
/// replicate loop (the coordinator's experiment runner) surface these as
/// per-job failures rather than aborting the whole cell.
pub fn parallel_map_catch<T, R, F>(
    items: Vec<T>,
    threads: usize,
    f: F,
) -> Vec<Result<R, String>>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    parallel_map_core(items, threads, f).into_iter().map(|r| r.map_err(panic_message)).collect()
}

/// Fork-join parallel map over `items`, preserving order, using scoped
/// threads (`threads` capped by item count; `threads == 1` runs inline).
///
/// Panic-safe: a panicking `f` is caught on its worker, the remaining
/// items are still processed, no shared mutex is ever poisoned, and the
/// first (lowest-index) panic payload is re-raised on the calling thread
/// once every item has been processed — the caller sees the panic, never
/// a hang. To observe per-item failures instead, use
/// [`parallel_map_catch`].
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let mut out = Vec::with_capacity(items.len());
    let mut first_panic: Option<PanicPayload> = None;
    for r in parallel_map_core(items, threads, f) {
        match r {
            Ok(v) => out.push(v),
            Err(p) => {
                if first_panic.is_none() {
                    first_panic = Some(p);
                }
            }
        }
    }
    if let Some(p) = first_panic {
        std::panic::resume_unwind(p);
    }
    out
}

/// [`parallel_map`] with a small-input fast path: when `total_work`
/// (any monotone work estimate — the `la` kernels pass a flop count)
/// is below `min_parallel_work`, every item runs inline on the calling
/// thread with no fork, no queue, and no span bookkeeping.
///
/// The inline and forked paths compute bit-identical results for the
/// workloads this crate fans out (disjoint output panels with fixed
/// per-element arithmetic), so the threshold is purely a performance
/// knob — see [`crate::la::Tune::par_min_flops`].
pub fn parallel_map_hinted<T, R, F>(
    items: Vec<T>,
    threads: usize,
    total_work: usize,
    min_parallel_work: usize,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let threads = if total_work < min_parallel_work { 1 } else { threads };
    parallel_map(items, threads, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs_exactly_once() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..57).collect();
        let out = parallel_map(items, 4, |_, x| x * 2);
        assert_eq!(out, (0..57).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread_inline() {
        let out = parallel_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_hinted_inline_and_forked_agree() {
        let items: Vec<usize> = (0..33).collect();
        let want: Vec<usize> = (0..33).map(|x| x * 3 + 1).collect();
        // below the threshold: runs inline on the caller
        let inline = parallel_map_hinted(items.clone(), 8, 100, 1_000_000, |_, x| x * 3 + 1);
        assert_eq!(inline, want);
        // at/above the threshold: forks, same results in the same order
        let forked = parallel_map_hinted(items, 8, 1_000_000, 1_000_000, |_, x| x * 3 + 1);
        assert_eq!(forked, want);
    }

    #[test]
    fn pool_survives_panicking_jobs() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..30 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 5 == 0 {
                    panic!("job {i} exploded");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // regression: this used to hang forever — a panicking job died
        // before decrementing the pending count
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 24);
        assert_eq!(pool.panicked_jobs(), 6);
        // the pool is still fully usable afterwards
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 25);
    }

    #[test]
    fn parallel_map_catch_surfaces_failures_in_place() {
        let out = parallel_map_catch((0..20).collect::<Vec<usize>>(), 4, |_, x| {
            if x % 7 == 3 {
                panic!("item {x} exploded");
            }
            x * 10
        });
        assert_eq!(out.len(), 20);
        for (i, r) in out.iter().enumerate() {
            if i % 7 == 3 {
                let msg = r.as_ref().unwrap_err();
                assert!(msg.contains("exploded"), "got {msg:?}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10);
            }
        }
        // inline (single-thread) path behaves identically
        let inline = parallel_map_catch(vec![1usize, 3], 1, |_, x| {
            if x == 3 {
                panic!("three");
            }
            x
        });
        assert_eq!(*inline[0].as_ref().unwrap(), 1);
        assert!(inline[1].is_err());
    }

    #[test]
    fn parallel_map_propagates_panic_after_draining() {
        let completed = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&completed);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map((0..40).collect::<Vec<usize>>(), 4, |_, x| {
                if x == 7 {
                    panic!("item {x} exploded");
                }
                c.fetch_add(1, Ordering::SeqCst);
                x
            })
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        let payload = result.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("item 7 exploded"), "got {msg:?}");
        // every non-panicking item still ran (no early abort, no hang)
        assert_eq!(completed.load(Ordering::SeqCst), 39);
    }
}
