//! Initialization strategies — the `limbo::init::*` policy family.
//! Produce the design points evaluated before the model-guided loop starts.

use crate::rng::{latin_hypercube, Pcg64};

/// An initial-design generator over `[0, 1]^dim`.
pub trait Initializer: Send + Sync {
    /// The initial sample locations.
    fn points(&self, dim: usize, rng: &mut Pcg64) -> Vec<Vec<f64>>;
}

/// No initialization (model-guided from the first sample).
#[derive(Clone, Debug, Default)]
pub struct NoInit;

impl Initializer for NoInit {
    fn points(&self, _dim: usize, _rng: &mut Pcg64) -> Vec<Vec<f64>> {
        Vec::new()
    }
}

/// `n` i.i.d. uniform points (Limbo's `init::RandomSampling`).
#[derive(Clone, Debug)]
pub struct RandomSampling {
    /// Number of samples.
    pub n: usize,
}

impl Initializer for RandomSampling {
    fn points(&self, dim: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
        (0..self.n).map(|_| rng.unit_point(dim)).collect()
    }
}

/// Full-factorial grid with `bins` levels per dimension (Limbo's
/// `init::GridSampling`).
#[derive(Clone, Debug)]
pub struct GridSampling {
    /// Levels per dimension.
    pub bins: usize,
}

impl Initializer for GridSampling {
    fn points(&self, dim: usize, _rng: &mut Pcg64) -> Vec<Vec<f64>> {
        let bins = self.bins.max(1);
        let total = (bins as u64).pow(dim as u32) as usize;
        let mut pts = Vec::with_capacity(total);
        for idx in 0..total {
            let mut rem = idx;
            let mut x = vec![0.0; dim];
            for d in 0..dim {
                let b = rem % bins;
                rem /= bins;
                x[d] = if bins == 1 { 0.5 } else { b as f64 / (bins - 1) as f64 };
            }
            pts.push(x);
        }
        pts
    }
}

/// Latin-hypercube design (BayesOpt's default initializer).
#[derive(Clone, Debug)]
pub struct Lhs {
    /// Number of samples.
    pub n: usize,
}

impl Initializer for Lhs {
    fn points(&self, dim: usize, rng: &mut Pcg64) -> Vec<Vec<f64>> {
        latin_hypercube(self.n, dim, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_bounds() {
        let mut rng = Pcg64::seed(1);
        assert!(NoInit.points(3, &mut rng).is_empty());
        let r = RandomSampling { n: 7 }.points(2, &mut rng);
        assert_eq!(r.len(), 7);
        let l = Lhs { n: 9 }.points(4, &mut rng);
        assert_eq!(l.len(), 9);
        for p in r.iter().chain(&l) {
            assert!(p.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn grid_covers_corners() {
        let mut rng = Pcg64::seed(2);
        let g = GridSampling { bins: 2 }.points(2, &mut rng);
        assert_eq!(g.len(), 4);
        assert!(g.contains(&vec![0.0, 0.0]));
        assert!(g.contains(&vec![1.0, 1.0]));
    }
}
