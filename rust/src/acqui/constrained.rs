//! Probability-of-feasibility–weighted acquisition for constrained BO.
//!
//! The classic constrained-EI construction (Gardner et al. 2014,
//! Gelbart et al. 2014): score a candidate by the base acquisition on
//! the *objective* posterior, weighted by the probability that every
//! constraint channel is satisfied under its own posterior,
//!
//! ```text
//! a_c(x) = a(x) · Π_j  Φ( μ_j(x) / σ_j(x) )
//! ```
//!
//! with the feasibility convention that a constraint value `>= 0` is
//! feasible (so `Φ(μ/σ) = P[c_j(x) >= 0]` under the channel's Gaussian
//! posterior). [`PofWeighted`] wraps any base [`AcquiFn`] over a
//! [`ModelBank`]: the base acquisition sees only the bank's objective
//! member, the feasibility weight comes from the constraint members.
//!
//! With **zero** constraint channels the wrapper returns the base score
//! untouched (bit-identical — pinned by the degenerate-case parity
//! tests), so it is always safe to build a constrained definition with
//! `k = 0`.

use crate::acqui::math::norm_cdf;
use crate::acqui::{AcquiContext, AcquiFn};
use crate::model::{Model, ModelBank};

/// Floor on a constraint channel's posterior std before dividing —
/// matches the guard [`crate::acqui::Pi`] uses for its own `Φ` argument.
const SIGMA_FLOOR: f64 = 1e-12;

/// A base acquisition weighted by the probability of feasibility.
///
/// Designed for nonnegative improvement-style bases (EI, PI), where the
/// product cleanly down-weights unlikely-feasible candidates. Bases that
/// can go *negative* (UCB with a pessimistic mean) are still handled
/// sanely: a negative score is scaled by `2 - PoF` instead, so
/// infeasibility always *penalizes* (drives the score further negative)
/// rather than accidentally boosting it toward zero, and the two
/// branches agree continuously at zero.
#[derive(Clone, Debug)]
pub struct PofWeighted<A> {
    /// The wrapped base acquisition, evaluated on the objective member.
    pub base: A,
}

impl<A> PofWeighted<A> {
    /// Weight `base` by the bank's probability of feasibility.
    pub fn new(base: A) -> Self {
        Self { base }
    }
}

impl<A> PofWeighted<A> {
    #[inline]
    fn weigh(base: f64, pof: f64) -> f64 {
        if base >= 0.0 {
            base * pof
        } else {
            base * (2.0 - pof)
        }
    }
}

impl<M: Model, A: AcquiFn<M>> AcquiFn<ModelBank<M>> for PofWeighted<A> {
    fn eval(&self, bank: &ModelBank<M>, x: &[f64], ctx: &AcquiContext) -> f64 {
        let base = self.base.eval(&bank.objective, x, ctx);
        if bank.constraints.is_empty() {
            return base;
        }
        let mut pof = 1.0;
        for c in &bank.constraints {
            let (mu, var) = c.predict(x);
            pof *= norm_cdf(mu / var.sqrt().max(SIGMA_FLOOR));
        }
        Self::weigh(base, pof)
    }

    /// One [`Model::predict_batch`] per constraint channel — the whole
    /// candidate population goes through each channel's batched
    /// posterior once, mirroring the base acquisition's batch path over
    /// the objective member.
    fn eval_batch(
        &self,
        bank: &ModelBank<M>,
        xs: &[Vec<f64>],
        ctx: &AcquiContext,
    ) -> Vec<f64> {
        let mut scores = self.base.eval_batch(&bank.objective, xs, ctx);
        if bank.constraints.is_empty() {
            return scores;
        }
        let mut pofs = vec![1.0; xs.len()];
        for c in &bank.constraints {
            for (p, (mu, var)) in pofs.iter_mut().zip(c.predict_batch(xs)) {
                *p *= norm_cdf(mu / var.sqrt().max(SIGMA_FLOOR));
            }
        }
        for (s, &p) in scores.iter_mut().zip(&pofs) {
            *s = Self::weigh(*s, p);
        }
        scores
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::{Ei, Ucb};
    use crate::kernel::Matern52;
    use crate::mean::ZeroMean;
    use crate::model::gp::Gp;
    use crate::rng::Pcg64;

    type DenseGp = Gp<Matern52, ZeroMean>;

    fn trained_bank(n_constraints: usize) -> ModelBank<DenseGp> {
        let mk = || Gp::new(Matern52::new(2), ZeroMean, 0.01);
        let mut bank =
            ModelBank::new(mk(), (0..n_constraints).map(|_| mk()).collect());
        let mut rng = Pcg64::seed(0xFEA5);
        for _ in 0..30 {
            let x = rng.unit_point(2);
            let y = -(x[0] - 0.8).powi(2) - (x[1] - 0.8).powi(2);
            bank.add_sample(&x, y);
            if n_constraints > 0 {
                // feasible only in the disk of radius 0.4 around (0.35, 0.35)
                let c =
                    0.16 - (x[0] - 0.35).powi(2) - (x[1] - 0.35).powi(2);
                let cs = vec![c; n_constraints];
                bank.add_constraint_sample(&x, &cs);
            }
        }
        bank
    }

    #[test]
    fn zero_constraints_is_bit_identical_to_the_base() {
        let bank = trained_bank(0);
        let acq = PofWeighted::new(Ei::default());
        let base = Ei::default();
        let ctx = AcquiContext::new(4, -0.1, 2);
        let cands: Vec<Vec<f64>> = (0..8)
            .map(|i| vec![0.1 + 0.1 * i as f64, 0.9 - 0.1 * i as f64])
            .collect();
        for c in &cands {
            let w = acq.eval(&bank, c, &ctx);
            let b = base.eval(&bank.objective, c, &ctx);
            assert_eq!(w.to_bits(), b.to_bits());
        }
        let wb = acq.eval_batch(&bank, &cands, &ctx);
        let bb = base.eval_batch(&bank.objective, &cands, &ctx);
        for (w, b) in wb.iter().zip(&bb) {
            assert_eq!(w.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pof_suppresses_the_infeasible_optimum() {
        // objective optimum at (0.8, 0.8) is outside the feasible disk:
        // the weighted score must prefer a feasible point over it
        let bank = trained_bank(1);
        let acq = PofWeighted::new(Ei { xi: 0.0 });
        let ctx = AcquiContext::new(8, f64::NEG_INFINITY, 2);
        let infeasible_opt = vec![0.85, 0.85];
        let feasible = vec![0.45, 0.45];
        let s_inf = acq.eval(&bank, &infeasible_opt, &ctx);
        let s_feas = acq.eval(&bank, &feasible, &ctx);
        assert!(
            s_feas > s_inf,
            "feasible {s_feas} should outrank infeasible optimum {s_inf}"
        );
        // and the weight really is the per-channel PoF product
        let base = Ei { xi: 0.0 }.eval(&bank.objective, &infeasible_opt, &ctx);
        let (mu, var) = bank.constraint(0).predict(&infeasible_opt);
        let pof = norm_cdf(mu / var.sqrt().max(SIGMA_FLOOR));
        assert!((s_inf - base * pof).abs() < 1e-15);
        assert!(pof < 0.5, "deep infeasible point should have low PoF: {pof}");
    }

    #[test]
    fn eval_batch_matches_pointwise() {
        let bank = trained_bank(2);
        let acq = PofWeighted::new(Ei::default());
        let ctx = AcquiContext::new(3, -0.05, 2);
        let cands: Vec<Vec<f64>> = (0..9)
            .map(|i| vec![(i % 3) as f64 * 0.4 + 0.1, (i / 3) as f64 * 0.4 + 0.1])
            .collect();
        let batch = acq.eval_batch(&bank, &cands, &ctx);
        for (j, c) in cands.iter().enumerate() {
            let v = acq.eval(&bank, c, &ctx);
            assert!(
                (batch[j] - v).abs() < 1e-10,
                "batch[{j}]={} vs pointwise {v}",
                batch[j]
            );
        }
    }

    #[test]
    fn negative_base_scores_are_penalized_not_boosted_by_infeasibility() {
        let bank = trained_bank(1);
        // alpha=0 UCB = posterior mean, negative everywhere on this toy
        let acq = PofWeighted::new(Ucb { alpha: 0.0 });
        let ctx = AcquiContext::new(2, f64::NEG_INFINITY, 2);
        let x = vec![0.2, 0.9]; // infeasible, objective clearly negative
        let base = Ucb { alpha: 0.0 }.eval(&bank.objective, &x, &ctx);
        assert!(base < 0.0, "toy objective mean should be negative: {base}");
        let weighted = acq.eval(&bank, &x, &ctx);
        assert!(
            weighted < base,
            "infeasibility must penalize a negative base: {weighted} vs {base}"
        );
    }
}
