//! Standard-normal pdf/cdf (no libm special functions in scope —
//! erf via the Abramowitz & Stegun 7.1.26 rational approximation,
//! |error| < 1.5e-7, plenty for acquisition ranking).

/// Standard normal density.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via `0.5 (1 + erf(z / sqrt2))`.
#[inline]
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function (A&S 7.1.26).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 5e-8);
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095).abs() < 2e-7);
    }

    #[test]
    fn cdf_symmetry_and_tails() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 5e-8);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        for z in [-2.0, -0.5, 0.3, 1.7] {
            assert!((norm_cdf(z) + norm_cdf(-z) - 1.0).abs() < 1e-7);
        }
        assert!(norm_cdf(-8.0) < 1e-10);
        assert!(norm_cdf(8.0) > 1.0 - 1e-10);
    }

    #[test]
    fn pdf_is_density_shaped() {
        assert!((norm_pdf(0.0) - 0.3989422804).abs() < 1e-9);
        assert!(norm_pdf(1.0) < norm_pdf(0.0));
        assert!((norm_pdf(2.0) - norm_pdf(-2.0)).abs() < 1e-15);
    }
}
