//! Joint-posterior batch acquisitions — scoring a whole q-point proposal
//! at once instead of one point at a time.
//!
//! The constant-liar heuristic ([`crate::coordinator::AskTellServer`]'s
//! original `ask_batch`) builds a batch greedily by re-maximizing a
//! *pointwise* acquisition on a model fed its own posterior mean: cheap
//! (q ordinary maximizations) but blind to the joint posterior — the lie
//! only deflates variance locally, and the correlation between batch
//! points never enters the score. The principled alternative shipped here
//! is Monte-Carlo **qEI** (multi-point expected improvement, the
//! GPflowOpt/NUBO approach):
//!
//! ```text
//! qEI(X) = E[ max(0, max_j f(x_j) − y*) ],   f ~ N(mu(X), Σ(X))
//! ```
//!
//! with `(mu, Σ)` the *joint* posterior over the batch
//! ([`Model::predict_joint`]) — a batch of strongly correlated points
//! shares one sample path and scores barely better than its best member,
//! so the estimator intrinsically rewards diversity where it matters and
//! tolerates clustering where the posterior is independent.
//!
//! The expectation has no closed form for q > 1; [`QEi`] estimates it
//! with correlated Gaussian draws `mu + L z` (`L L^T = Σ` via a jittered
//! Cholesky, `z` standard normal). The draws are **common random
//! numbers**: one frozen, antithetic `S x q` block of normals per
//! [`QEi`] instance, so the estimator is a *deterministic* function of
//! the batch — the inner optimizers see a smooth(ish) fixed landscape
//! over the flattened `q·d`-dimensional batch vector
//! ([`BatchAcquiObjective`]) instead of a noisy one, and per-sample
//! maxima are exactly monotone under batch extension (the greedy
//! marginal-gain loop in [`propose_batch_qei`] relies on this).
//!
//! Cost per evaluation: one joint posterior (`O(n·B²)` on top of the
//! batched predict for the dense GP, `O(m·B²)` sparse), one `B x B`
//! Cholesky, and `S·B²/2` multiply-adds of sample paths — a few hundred
//! times a pointwise EI evaluation at q = 4, S = 512. Pick the constant
//! liar when proposal latency dominates (embedded ask/tell loops), qEI
//! when evaluations are expensive enough that batch quality pays for the
//! extra proposal compute.

use crate::acqui::{incumbent_for, AcquiContext};
use crate::la::spd_factor_jittered;
use crate::model::Model;
use crate::obs::{self, Counter, Phase};
use crate::opt::{Objective, Optimizer};
use crate::rng::Pcg64;

/// A joint acquisition over candidate *batches* (higher = better).
///
/// Unlike [`crate::acqui::AcquiFn::eval_batch`], which scores B
/// candidates independently, `eval_joint` returns a single score for the
/// whole batch, so correlations between the points enter the ranking.
pub trait BatchAcquiFn<M: Model + ?Sized>: Send + Sync {
    /// Joint score of `batch` as one q-point proposal.
    fn eval_joint(&self, model: &M, batch: &[Vec<f64>], ctx: &AcquiContext) -> f64;
}

/// Monte-Carlo multi-point expected improvement with frozen common
/// random numbers (see the [module docs](self) for the estimator).
///
/// One instance = one frozen CRN block = one deterministic acquisition
/// landscape; build a fresh instance (new seed) per proposal round so
/// successive rounds do not chase the same noise realization.
#[derive(Clone, Debug)]
pub struct QEi {
    /// Exploration jitter added to the incumbent threshold (as in
    /// [`crate::acqui::Ei`]).
    pub xi: f64,
    mc_samples: usize,
    max_q: usize,
    /// Frozen standard-normal draws, row-major `mc_samples x max_q`;
    /// the second half mirrors the first (antithetic pairs).
    crn: Vec<f64>,
}

impl QEi {
    /// Freeze `mc_samples` antithetic CRN draws for batches up to
    /// `max_q` points (`mc_samples` is rounded down to even).
    pub fn new(mc_samples: usize, max_q: usize, seed: u64) -> Self {
        assert!(max_q >= 1, "qEI needs room for at least one point");
        let half = (mc_samples / 2).max(1);
        let mut rng = Pcg64::seed(seed);
        let mut crn = Vec::with_capacity(2 * half * max_q);
        for _ in 0..half * max_q {
            crn.push(rng.normal());
        }
        // antithetic mirror: halves the estimator variance for the
        // monotone-in-f integrand at zero additional draws
        let mirror: Vec<f64> = crn.iter().map(|&v| -v).collect();
        crn.extend(mirror);
        Self { xi: 0.01, mc_samples: 2 * half, max_q, crn }
    }

    /// Override the exploration jitter.
    pub fn with_xi(mut self, xi: f64) -> Self {
        self.xi = xi;
        self
    }

    /// Number of (antithetic) MC draws per evaluation.
    pub fn mc_samples(&self) -> usize {
        self.mc_samples
    }

    /// Largest batch the frozen CRN block supports.
    pub fn max_q(&self) -> usize {
        self.max_q
    }
}

impl<M: Model + ?Sized> BatchAcquiFn<M> for QEi {
    fn eval_joint(&self, model: &M, batch: &[Vec<f64>], ctx: &AcquiContext) -> f64 {
        let _span = obs::span(Phase::QeiMc);
        obs::counter_add(Counter::QeiMcDraws, self.mc_samples as u64);
        let q = batch.len();
        assert!(q >= 1, "qEI of an empty batch");
        assert!(
            q <= self.max_q,
            "batch size {q} exceeds the frozen CRN width {}",
            self.max_q
        );
        let (mus, cov) = model.predict_joint(batch);
        let threshold = incumbent_for(model, ctx, &mus) + self.xi;
        let mut path = vec![0.0; q];
        let mut acc = 0.0;
        // near-duplicate batches make Σ numerically semi-definite: the
        // jittered factor escalates the diagonal until it goes through
        match spd_factor_jittered(&cov, 1e-2) {
            Ok((l, _)) => {
                for s in 0..self.mc_samples {
                    let z = &self.crn[s * self.max_q..s * self.max_q + q];
                    l.mul_lower_into(z, &mut path);
                    let mut best_gain = 0.0;
                    for j in 0..q {
                        let gain = mus[j] + path[j] - threshold;
                        if gain > best_gain {
                            best_gain = gain;
                        }
                    }
                    acc += best_gain;
                }
            }
            Err(_) => {
                // irrecoverably non-PSD covariance (pathological model):
                // degrade to independent draws on the clamped diagonal
                let sig: Vec<f64> =
                    (0..q).map(|j| cov[(j, j)].max(0.0).sqrt()).collect();
                for s in 0..self.mc_samples {
                    let z = &self.crn[s * self.max_q..s * self.max_q + q];
                    let mut best_gain = 0.0;
                    for j in 0..q {
                        let gain = mus[j] + sig[j] * z[j] - threshold;
                        if gain > best_gain {
                            best_gain = gain;
                        }
                    }
                    acc += best_gain;
                }
            }
        }
        acc / self.mc_samples as f64
    }
}

/// A [`BatchAcquiFn`] bound to a model and context as a maximization
/// [`Objective`] over the **flattened batch vector** `[x_1 | x_2 | ...]`
/// of dimension `q·d` — the adapter that lets every inner optimizer
/// (random restarts, Nelder–Mead, CMA-ES, ...) search batch space
/// directly.
pub struct BatchAcquiObjective<'a, M: Model + ?Sized, A: BatchAcquiFn<M>> {
    model: &'a M,
    acqui: &'a A,
    ctx: AcquiContext,
    q: usize,
    dim: usize,
}

impl<'a, M: Model + ?Sized, A: BatchAcquiFn<M>> BatchAcquiObjective<'a, M, A> {
    /// Bind `acqui` over `model` for one proposal round of `q` points in
    /// `dim` dimensions.
    pub fn new(model: &'a M, acqui: &'a A, ctx: AcquiContext, q: usize, dim: usize) -> Self {
        assert!(q >= 1 && dim >= 1);
        Self { model, acqui, ctx, q, dim }
    }

    /// Flattened search dimensionality `q·d`.
    pub fn flat_dim(&self) -> usize {
        self.q * self.dim
    }
}

impl<M: Model + ?Sized, A: BatchAcquiFn<M>> Objective for BatchAcquiObjective<'_, M, A> {
    fn eval(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.q * self.dim, "flattened batch length mismatch");
        let batch: Vec<Vec<f64>> = x.chunks(self.dim).map(<[f64]>::to_vec).collect();
        self.acqui.eval_joint(self.model, &batch, &self.ctx)
    }
}

/// Propose a `q`-point batch maximizing `acqui`'s joint score:
/// greedy marginal-gain construction (q single-point maximizations of
/// the joint score of `partial ∪ {x}` — the cheap, order-robust
/// fallback), then one joint refinement pass over the flattened
/// `q·d`-dimensional batch vector seeded at the greedy solution, keeping
/// whichever scores higher. With a CRN-frozen estimator ([`QEi`]) the
/// greedy gains are exact per-sample monotone, so the construction never
/// pays for MC noise between steps.
pub fn propose_batch_qei<M, A, O>(
    model: &M,
    acqui: &A,
    inner: &O,
    ctx: AcquiContext,
    dim: usize,
    q: usize,
    rng: &mut Pcg64,
) -> Vec<Vec<f64>>
where
    M: Model + ?Sized,
    A: BatchAcquiFn<M>,
    O: Optimizer + ?Sized,
{
    let q = q.max(1);
    // greedy marginal gain: arg max_x acqui(batch ∪ {x})
    let mut batch: Vec<Vec<f64>> = Vec::with_capacity(q);
    for _ in 0..q {
        let best = {
            let partial = &batch;
            let marginal = |x: &[f64]| {
                let mut cand: Vec<Vec<f64>> = Vec::with_capacity(partial.len() + 1);
                cand.extend(partial.iter().cloned());
                cand.push(x.to_vec());
                acqui.eval_joint(model, &cand, &ctx)
            };
            inner.optimize(&marginal, dim, rng)
        };
        batch.push(best.x);
    }
    // joint refinement over the flattened batch vector
    let objective = BatchAcquiObjective::new(model, acqui, ctx, q, dim);
    let flat: Vec<f64> = batch.iter().flatten().copied().collect();
    let greedy_score = objective.eval(&flat);
    let refined = inner.optimize_from(&objective, &flat, rng);
    if refined.value.is_finite() && refined.value > greedy_score {
        refined.x.chunks(dim).map(<[f64]>::to_vec).collect()
    } else {
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acqui::{AcquiFn, Ei};
    use crate::kernel::Matern52;
    use crate::mean::DataMean;
    use crate::model::gp::Gp;
    use crate::model::Model;
    use crate::opt::{NelderMead, OptimizerExt, RandomPoint};

    fn fitted_gp() -> Gp<Matern52, DataMean> {
        let mut rng = Pcg64::seed(0xBEEF);
        let xs: Vec<Vec<f64>> = (0..14).map(|_| rng.unit_point(2)).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| (5.0 * x[0]).sin() + x[1] * 0.7).collect();
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        gp
    }

    #[test]
    fn qei_at_q1_matches_analytic_ei_within_mc_tolerance() {
        let gp = fitted_gp();
        let best = gp.best_observation().unwrap();
        let ctx = AcquiContext::new(3, best, 2);
        let qei = QEi::new(4096, 1, 0xC12).with_xi(0.01);
        let ei = Ei { xi: 0.01 };
        for probe in [[0.05, 0.9], [0.5, 0.5], [0.92, 0.13], [0.3, 0.7]] {
            let mc = qei.eval_joint(&gp, &[probe.to_vec()], &ctx);
            let analytic = ei.eval(&gp, &probe, &ctx);
            let tol = 0.05 * 1.0_f64.max(analytic.abs());
            assert!(
                (mc - analytic).abs() <= tol,
                "probe {probe:?}: MC {mc} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn qei_is_deterministic_and_monotone_under_batch_extension() {
        let gp = fitted_gp();
        let ctx = AcquiContext::new(2, gp.best_observation().unwrap(), 2);
        let qei = QEi::new(256, 3, 7);
        let a = vec![0.2, 0.6];
        let b = vec![0.8, 0.1];
        let single = qei.eval_joint(&gp, std::slice::from_ref(&a), &ctx);
        let single2 = qei.eval_joint(&gp, std::slice::from_ref(&a), &ctx);
        assert_eq!(single, single2, "frozen CRN must make qEI deterministic");
        // same CRN, extended batch: the per-sample max can only grow
        // (the first point's sample path is shared bit-for-bit)
        let pair = qei.eval_joint(&gp, &[a.clone(), b], &ctx);
        assert!(
            pair >= single - 1e-9,
            "extension must not lose value: {pair} < {single}"
        );
        // a duplicated point adds (almost) nothing, a distinct one more
        // (1e-3 slack: the duplicate's rank-one covariance takes the
        // jittered factor path, which perturbs its second sample path)
        let dup = qei.eval_joint(&gp, &[a.clone(), a.clone()], &ctx);
        assert!(dup <= pair + 1e-3, "duplicate ({dup}) should not beat diversity ({pair})");
        assert!(dup.is_finite() && dup >= 0.0);
    }

    #[test]
    fn flattened_objective_matches_eval_joint() {
        let gp = fitted_gp();
        let ctx = AcquiContext::new(1, gp.best_observation().unwrap(), 2);
        let qei = QEi::new(128, 2, 99);
        let obj = BatchAcquiObjective::new(&gp, &qei, ctx, 2, 2);
        assert_eq!(obj.flat_dim(), 4);
        let flat = [0.1, 0.9, 0.7, 0.3];
        let direct =
            qei.eval_joint(&gp, &[vec![0.1, 0.9], vec![0.7, 0.3]], &ctx);
        assert_eq!(obj.eval(&flat), direct);
    }

    #[test]
    fn propose_batch_qei_returns_q_points_in_bounds() {
        let gp = fitted_gp();
        let ctx = AcquiContext::new(4, gp.best_observation().unwrap(), 2);
        let qei = QEi::new(1024, 4, 0xAB);
        let inner = RandomPoint::new(64).then(NelderMead::default()).restarts(2, 2);
        let mut rng = Pcg64::seed(11);
        let batch = propose_batch_qei(&gp, &qei, &inner, ctx, 2, 4, &mut rng);
        assert_eq!(batch.len(), 4);
        for x in &batch {
            assert_eq!(x.len(), 2);
            assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)), "{x:?}");
        }
        // the proposed batch must score at least as well as its own best
        // single point (monotone extension + greedy construction; 0.02
        // slack covers MC noise between different CRN columns)
        let joint = qei.eval_joint(&gp, &batch, &ctx);
        let best_single = batch
            .iter()
            .map(|x| qei.eval_joint(&gp, std::slice::from_ref(x), &ctx))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(joint >= best_single - 0.02, "joint {joint} vs single {best_single}");
    }
}
