//! Acquisition functions — the `limbo::acqui::*` policy family.
//!
//! Each acquisition scores a candidate from the model posterior and the
//! run context (iteration count for GP-UCB, incumbent best for EI/PI).
//! All are generic over [`Model`], so they work identically on the native
//! [`crate::model::gp::Gp`] and the XLA-artifact backend.

mod math;

pub use math::{norm_cdf, norm_pdf};

use crate::model::Model;

/// Run context the optimizer passes to the acquisition at each iteration.
#[derive(Clone, Copy, Debug)]
pub struct AcquiContext {
    /// Current BO iteration (number of non-init samples so far).
    pub iteration: usize,
    /// Incumbent best observation (max), `-inf` before any data.
    pub best: f64,
    /// Problem dimensionality.
    pub dim: usize,
}

impl AcquiContext {
    /// Context for a fresh run.
    pub fn start(dim: usize) -> Self {
        Self { iteration: 0, best: f64::NEG_INFINITY, dim }
    }
}

/// An acquisition function over model `M`.
pub trait AcquiFn<M: Model + ?Sized>: Send + Sync {
    /// Score candidate `x` (higher = more promising).
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64;
}

/// Upper Confidence Bound: `mu + alpha * sigma` (Limbo's `acqui::UCB`).
#[derive(Clone, Debug)]
pub struct Ucb {
    /// Exploration weight.
    pub alpha: f64,
}

impl Default for Ucb {
    fn default() -> Self {
        Self { alpha: 0.5 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Ucb {
    fn eval(&self, model: &M, x: &[f64], _ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        mu + self.alpha * var.sqrt()
    }
}

/// GP-UCB (Srinivas et al. 2010) with the theoretical beta schedule
/// `beta_t = sqrt(2 log(t^(d/2+2) pi^2 / (3 delta)))` (Limbo's
/// `acqui::GP_UCB`).
#[derive(Clone, Debug)]
pub struct GpUcb {
    /// Confidence parameter (smaller = more exploration).
    pub delta: f64,
}

impl Default for GpUcb {
    fn default() -> Self {
        Self { delta: 0.1 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for GpUcb {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let t = (ctx.iteration + 1) as f64;
        let d = ctx.dim as f64;
        let beta2 = 2.0
            * (t.powf(d / 2.0 + 2.0) * std::f64::consts::PI.powi(2) / (3.0 * self.delta))
                .ln();
        let (mu, var) = model.predict(x);
        mu + beta2.max(0.0).sqrt() * var.sqrt()
    }
}

/// Expected Improvement over the incumbent (BayesOpt's default criterion).
#[derive(Clone, Debug)]
pub struct Ei {
    /// Exploration jitter `xi`.
    pub xi: f64,
}

impl Default for Ei {
    fn default() -> Self {
        Self { xi: 0.01 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Ei {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        let sigma = var.sqrt();
        let best = if ctx.best.is_finite() { ctx.best } else { 0.0 };
        if sigma < 1e-12 {
            return (mu - best - self.xi).max(0.0);
        }
        let z = (mu - best - self.xi) / sigma;
        (mu - best - self.xi) * norm_cdf(z) + sigma * norm_pdf(z)
    }
}

/// Probability of Improvement.
#[derive(Clone, Debug)]
pub struct Pi {
    /// Exploration jitter `xi`.
    pub xi: f64,
}

impl Default for Pi {
    fn default() -> Self {
        Self { xi: 0.01 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Pi {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        let sigma = var.sqrt().max(1e-12);
        let best = if ctx.best.is_finite() { ctx.best } else { 0.0 };
        norm_cdf((mu - best - self.xi) / sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::SquaredExpArd;
    use crate::mean::ZeroMean;
    use crate::model::gp::Gp;
    use crate::model::Model;

    fn fitted_gp() -> Gp<SquaredExpArd, ZeroMean> {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        gp.fit(&[vec![0.2], vec![0.8]], &[1.0, -1.0]);
        gp
    }

    #[test]
    fn ucb_prefers_uncertain_far_points_with_big_alpha() {
        let gp = fitted_gp();
        let ctx = AcquiContext { iteration: 1, best: 1.0, dim: 1 };
        let explore = Ucb { alpha: 100.0 };
        // x=0.5 is between data (low sigma); x=5 is far (sigma ~ prior)
        assert!(explore.eval(&gp, &[5.0], &ctx) > explore.eval(&gp, &[0.5], &ctx));
        // alpha = 0 reduces to the posterior mean
        let exploit = Ucb { alpha: 0.0 };
        let (mu, _) = gp.predict(&[0.3]);
        assert!((exploit.eval(&gp, &[0.3], &ctx) - mu).abs() < 1e-12);
    }

    #[test]
    fn gp_ucb_beta_grows_with_iteration() {
        let gp = fitted_gp();
        let a = GpUcb::default();
        let early = AcquiContext { iteration: 1, best: 1.0, dim: 1 };
        let late = AcquiContext { iteration: 1000, best: 1.0, dim: 1 };
        // at a fixed point, larger t -> larger bonus
        let x = [3.0];
        assert!(a.eval(&gp, &x, &late) > a.eval(&gp, &x, &early));
    }

    #[test]
    fn ei_zero_when_certain_and_worse() {
        let gp = fitted_gp();
        let ei = Ei { xi: 0.0 };
        let ctx = AcquiContext { iteration: 1, best: 5.0, dim: 1 };
        // at the observed minimum, mu ~ -1 << best=5, sigma tiny
        let v = ei.eval(&gp, &[0.8], &ctx);
        assert!(v >= 0.0 && v < 1e-3, "ei={v}");
    }

    #[test]
    fn ei_positive_under_uncertainty() {
        let gp = fitted_gp();
        let ei = Ei::default();
        let ctx = AcquiContext { iteration: 1, best: 1.0, dim: 1 };
        assert!(ei.eval(&gp, &[10.0], &ctx) > 0.0);
    }

    #[test]
    fn pi_bounded_by_one() {
        let gp = fitted_gp();
        let pi = Pi::default();
        let ctx = AcquiContext { iteration: 1, best: -10.0, dim: 1 };
        let v = pi.eval(&gp, &[0.2], &ctx);
        assert!(v > 0.9 && v <= 1.0, "pi={v}");
    }
}
