//! Acquisition functions — the `limbo::acqui::*` policy family.
//!
//! Each acquisition scores a candidate from the model posterior and the
//! run context (iteration count for GP-UCB, incumbent best for EI/PI).
//! All are generic over [`Model`], so they work identically on the native
//! [`crate::model::gp::Gp`] and the XLA-artifact backend.

pub mod batch;
pub mod constrained;
mod math;

pub use batch::{BatchAcquiFn, BatchAcquiObjective, QEi};
pub use constrained::PofWeighted;
pub use math::{norm_cdf, norm_pdf};

use crate::model::Model;
use crate::obs::{self, Phase};
use crate::opt::Objective;

/// Incumbent threshold for the improvement-based acquisitions (EI/PI/qEI).
///
/// When the model carries per-observation noise
/// ([`Model::has_noisy_observations`]), the max *raw* observation is a
/// biased incumbent — the largest sample is the one whose noise drew
/// highest, so EI/PI would chase a threshold no true function value ever
/// reached. In that case the best *predicted mean* over the training
/// inputs ([`Model::best_predicted_mean`]) is the right threshold and
/// takes priority over everything else.
///
/// Otherwise this prefers the run context's incumbent; before any `tell`
/// the context carries `-inf`, in which case the *model's* best
/// observation is the correct threshold (a server wrapped around a
/// pre-fitted model used to silently substitute `0.0` here — wrong for
/// objectives whose values live far from 0). Only when the model has no
/// data either does this fall back to the best *predicted* mean of the
/// candidates (and 0.0 as the final no-information default).
///
/// `mus` is the caller's candidate pool: the whole batch for
/// `eval_batch`, the single candidate's mean for a pointwise `eval`. In
/// that last-resort branch the two can therefore use different
/// thresholds — harmless in practice, because a model with no data
/// predicts a *constant* prior mean for every standard [`crate::mean`]
/// function, making the per-candidate and per-batch maxima identical.
pub(crate) fn incumbent_for<M: Model + ?Sized>(
    model: &M,
    ctx: &AcquiContext,
    mus: &[f64],
) -> f64 {
    if model.has_noisy_observations() {
        if let Some(b) = model.best_predicted_mean() {
            if b.is_finite() {
                return b;
            }
        }
    }
    if ctx.best.is_finite() {
        return ctx.best;
    }
    if let Some(b) = model.best_observation() {
        if b.is_finite() {
            return b;
        }
    }
    let m = mus.iter().copied().filter(|v| v.is_finite()).fold(f64::NEG_INFINITY, f64::max);
    if m.is_finite() {
        m
    } else {
        0.0
    }
}

/// Run context the optimizer passes to the acquisition at each iteration.
///
/// Built once per iteration via [`AcquiContext::new`], which precomputes
/// the iteration-dependent part of the GP-UCB β schedule — the inner
/// optimizer scores hundreds of candidates per iteration, so per-candidate
/// `ln`/`powf` calls in [`GpUcb::eval`] were pure overhead.
#[derive(Clone, Copy, Debug)]
pub struct AcquiContext {
    // All fields are read-only (construct a fresh context per iteration
    // via `new`): `gp_ucb_beta2` is derived from `iteration`/`dim`, so
    // field mutation could silently desync the cached schedule.
    iteration: usize,
    best: f64,
    dim: usize,
    /// δ-independent part of the GP-UCB β² schedule,
    /// `2 ln(t^(d/2+2) π² / 3)`; [`GpUcb`] adds its own `-2 ln δ`.
    gp_ucb_beta2: f64,
}

impl AcquiContext {
    /// Context for iteration `iteration` with incumbent `best`.
    pub fn new(iteration: usize, best: f64, dim: usize) -> Self {
        let t = (iteration + 1) as f64;
        let d = dim as f64;
        let gp_ucb_beta2 = 2.0
            * ((d / 2.0 + 2.0) * t.ln() + (std::f64::consts::PI.powi(2) / 3.0).ln());
        Self { iteration, best, dim, gp_ucb_beta2 }
    }

    /// Context for a fresh run.
    pub fn start(dim: usize) -> Self {
        Self::new(0, f64::NEG_INFINITY, dim)
    }

    /// Current BO iteration (number of non-init samples so far).
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    /// Incumbent best observation (max), `-inf` before any data.
    pub fn best(&self) -> f64 {
        self.best
    }

    /// Problem dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// An acquisition function over model `M`.
pub trait AcquiFn<M: Model + ?Sized>: Send + Sync {
    /// Score candidate `x` (higher = more promising).
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64;

    /// Score a whole candidate slice through the model's batched
    /// posterior ([`Model::predict_batch`]). Per-batch constants (GP-UCB's
    /// β, the incumbent threshold) are computed once per batch instead of
    /// once per candidate. Default loops over [`eval`](Self::eval).
    fn eval_batch(&self, model: &M, xs: &[Vec<f64>], ctx: &AcquiContext) -> Vec<f64> {
        xs.iter().map(|x| self.eval(model, x, ctx)).collect()
    }
}

/// An acquisition bound to a model and context as a maximization
/// [`Objective`] for the inner optimizers, with `eval_many` routed through
/// [`AcquiFn::eval_batch`] — the glue that lets population-based inner
/// optimizers ([`crate::opt::RandomPoint`], [`crate::opt::Cmaes`],
/// [`crate::opt::PopulationSearch`], ...) hit the batched posterior path.
pub struct AcquiObjective<'a, M: Model + ?Sized, A: AcquiFn<M>> {
    model: &'a M,
    acqui: &'a A,
    ctx: AcquiContext,
}

impl<'a, M: Model + ?Sized, A: AcquiFn<M>> AcquiObjective<'a, M, A> {
    /// Bind `acqui` over `model` for one iteration.
    pub fn new(model: &'a M, acqui: &'a A, ctx: AcquiContext) -> Self {
        Self { model, acqui, ctx }
    }
}

impl<M: Model + ?Sized, A: AcquiFn<M>> Objective for AcquiObjective<'_, M, A> {
    fn eval(&self, x: &[f64]) -> f64 {
        self.acqui.eval(self.model, x, &self.ctx)
    }

    fn eval_many(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let _span = obs::span(Phase::AcquiBatch);
        self.acqui.eval_batch(self.model, xs, &self.ctx)
    }
}

/// Upper Confidence Bound: `mu + alpha * sigma` (Limbo's `acqui::UCB`).
#[derive(Clone, Debug)]
pub struct Ucb {
    /// Exploration weight.
    pub alpha: f64,
}

impl Default for Ucb {
    fn default() -> Self {
        Self { alpha: 0.5 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Ucb {
    fn eval(&self, model: &M, x: &[f64], _ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        mu + self.alpha * var.sqrt()
    }

    fn eval_batch(&self, model: &M, xs: &[Vec<f64>], _ctx: &AcquiContext) -> Vec<f64> {
        model
            .predict_batch(xs)
            .into_iter()
            .map(|(mu, var)| mu + self.alpha * var.sqrt())
            .collect()
    }
}

/// GP-UCB (Srinivas et al. 2010) with the theoretical beta schedule
/// `beta_t = sqrt(2 log(t^(d/2+2) pi^2 / (3 delta)))` (Limbo's
/// `acqui::GP_UCB`).
#[derive(Clone, Debug)]
pub struct GpUcb {
    /// Confidence parameter (smaller = more exploration).
    pub delta: f64,
}

impl Default for GpUcb {
    fn default() -> Self {
        Self { delta: 0.1 }
    }
}

impl GpUcb {
    /// β for the current iteration: the `t`/`d` part comes precomputed
    /// from [`AcquiContext::new`], only `-2 ln δ` is added here — no
    /// `powf` and no per-candidate schedule recomputation.
    fn beta(&self, ctx: &AcquiContext) -> f64 {
        (ctx.gp_ucb_beta2 - 2.0 * self.delta.ln()).max(0.0).sqrt()
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for GpUcb {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let beta = self.beta(ctx);
        let (mu, var) = model.predict(x);
        mu + beta * var.sqrt()
    }

    fn eval_batch(&self, model: &M, xs: &[Vec<f64>], ctx: &AcquiContext) -> Vec<f64> {
        let beta = self.beta(ctx); // once per batch, not per candidate
        model
            .predict_batch(xs)
            .into_iter()
            .map(|(mu, var)| mu + beta * var.sqrt())
            .collect()
    }
}

/// Expected Improvement over the incumbent (BayesOpt's default criterion).
#[derive(Clone, Debug)]
pub struct Ei {
    /// Exploration jitter `xi`.
    pub xi: f64,
}

impl Default for Ei {
    fn default() -> Self {
        Self { xi: 0.01 }
    }
}

impl Ei {
    /// Analytic EI, clamped at 0: the A&S-7.1.26 `norm_cdf` carries an
    /// absolute error of ~1.5e-7, which can drive the closed form
    /// microscopically negative deep in the left tail (large negative z)
    /// — a negative "expected improvement" breaks nonnegativity
    /// invariants downstream (and qEI's MC estimator is nonnegative by
    /// construction, so the analytic form should be too).
    #[inline]
    fn score(&self, mu: f64, var: f64, threshold: f64) -> f64 {
        let sigma = var.sqrt();
        let gain = mu - threshold;
        if sigma < 1e-12 {
            return gain.max(0.0);
        }
        let z = gain / sigma;
        (gain * norm_cdf(z) + sigma * norm_pdf(z)).max(0.0)
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Ei {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        let threshold = incumbent_for(model, ctx, std::slice::from_ref(&mu)) + self.xi;
        self.score(mu, var, threshold)
    }

    fn eval_batch(&self, model: &M, xs: &[Vec<f64>], ctx: &AcquiContext) -> Vec<f64> {
        let preds = model.predict_batch(xs);
        let mus: Vec<f64> = preds.iter().map(|&(mu, _)| mu).collect();
        let threshold = incumbent_for(model, ctx, &mus) + self.xi;
        preds.into_iter().map(|(mu, var)| self.score(mu, var, threshold)).collect()
    }
}

/// Probability of Improvement.
#[derive(Clone, Debug)]
pub struct Pi {
    /// Exploration jitter `xi`.
    pub xi: f64,
}

impl Default for Pi {
    fn default() -> Self {
        Self { xi: 0.01 }
    }
}

impl<M: Model + ?Sized> AcquiFn<M> for Pi {
    fn eval(&self, model: &M, x: &[f64], ctx: &AcquiContext) -> f64 {
        let (mu, var) = model.predict(x);
        let sigma = var.sqrt().max(1e-12);
        let threshold = incumbent_for(model, ctx, std::slice::from_ref(&mu)) + self.xi;
        norm_cdf((mu - threshold) / sigma)
    }

    fn eval_batch(&self, model: &M, xs: &[Vec<f64>], ctx: &AcquiContext) -> Vec<f64> {
        let preds = model.predict_batch(xs);
        let mus: Vec<f64> = preds.iter().map(|&(mu, _)| mu).collect();
        let threshold = incumbent_for(model, ctx, &mus) + self.xi;
        preds
            .into_iter()
            .map(|(mu, var)| norm_cdf((mu - threshold) / var.sqrt().max(1e-12)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::SquaredExpArd;
    use crate::mean::ZeroMean;
    use crate::model::gp::Gp;
    use crate::model::Model;

    fn fitted_gp() -> Gp<SquaredExpArd, ZeroMean> {
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        gp.fit(&[vec![0.2], vec![0.8]], &[1.0, -1.0]);
        gp
    }

    #[test]
    fn ucb_prefers_uncertain_far_points_with_big_alpha() {
        let gp = fitted_gp();
        let ctx = AcquiContext::new(1, 1.0, 1);
        let explore = Ucb { alpha: 100.0 };
        // x=0.5 is between data (low sigma); x=5 is far (sigma ~ prior)
        assert!(explore.eval(&gp, &[5.0], &ctx) > explore.eval(&gp, &[0.5], &ctx));
        // alpha = 0 reduces to the posterior mean
        let exploit = Ucb { alpha: 0.0 };
        let (mu, _) = gp.predict(&[0.3]);
        assert!((exploit.eval(&gp, &[0.3], &ctx) - mu).abs() < 1e-12);
    }

    #[test]
    fn gp_ucb_beta_grows_with_iteration() {
        let gp = fitted_gp();
        let a = GpUcb::default();
        let early = AcquiContext::new(1, 1.0, 1);
        let late = AcquiContext::new(1000, 1.0, 1);
        // at a fixed point, larger t -> larger bonus
        let x = [3.0];
        assert!(a.eval(&gp, &x, &late) > a.eval(&gp, &x, &early));
    }

    #[test]
    fn ei_zero_when_certain_and_worse() {
        let gp = fitted_gp();
        let ei = Ei { xi: 0.0 };
        let ctx = AcquiContext::new(1, 5.0, 1);
        // at the observed minimum, mu ~ -1 << best=5, sigma tiny
        let v = ei.eval(&gp, &[0.8], &ctx);
        assert!(v >= 0.0 && v < 1e-3, "ei={v}");
    }

    #[test]
    fn ei_positive_under_uncertainty() {
        let gp = fitted_gp();
        let ei = Ei::default();
        let ctx = AcquiContext::new(1, 1.0, 1);
        assert!(ei.eval(&gp, &[10.0], &ctx) > 0.0);
    }

    #[test]
    fn pi_bounded_by_one() {
        let gp = fitted_gp();
        let pi = Pi::default();
        let ctx = AcquiContext::new(1, -10.0, 1);
        let v = pi.eval(&gp, &[0.2], &ctx);
        assert!(v > 0.9 && v <= 1.0, "pi={v}");
    }

    #[test]
    fn ei_pi_fall_back_to_model_incumbent_not_zero() {
        // all-negative observations: with the old `best = 0.0` substitute
        // the threshold sat far above every achievable value, flattening
        // EI/PI into a wrong (near-zero everywhere) landscape
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        gp.fit(&[vec![0.2], vec![0.8]], &[-120.0, -100.0]);
        let ctx = AcquiContext::start(1); // incumbent -inf (no tell yet)
        assert_eq!(incumbent_for(&gp, &ctx, &[-110.0]), -100.0);

        let ei = Ei { xi: 0.0 };
        let pi = Pi { xi: 0.0 };
        // near the better observation, improvement is genuinely plausible:
        // the fixed threshold (-100, not 0) must leave EI/PI responsive
        let v_ei = ei.eval(&gp, &[0.95], &ctx);
        let v_pi = pi.eval(&gp, &[0.95], &ctx);
        assert!(v_ei > 1e-3, "EI with model incumbent should be alive: {v_ei}");
        assert!(v_pi > 1e-3, "PI with model incumbent should be alive: {v_pi}");
        // batch path agrees with the pointwise path on the same fallback
        let cands = vec![vec![0.1], vec![0.5], vec![0.95]];
        let b_ei = ei.eval_batch(&gp, &cands, &ctx);
        let b_pi = pi.eval_batch(&gp, &cands, &ctx);
        for (j, c) in cands.iter().enumerate() {
            assert!((b_ei[j] - ei.eval(&gp, c, &ctx)).abs() < 1e-10);
            assert!((b_pi[j] - pi.eval(&gp, c, &ctx)).abs() < 1e-10);
        }
        // empty model + no incumbent: max predicted mean (prior = 0 here)
        let fresh = Gp::new(SquaredExpArd::new(1), ZeroMean, 0.01);
        assert_eq!(incumbent_for(&fresh, &ctx, &[]), 0.0);
    }

    #[test]
    fn noisy_incumbent_uses_best_predicted_mean_not_best_raw_sample() {
        // 1-D toy: flat true function at 0, one wild positive outlier
        // reported with huge per-observation noise. The raw max (5.0) is
        // pure noise; the posterior mean discounts it.
        let mut gp = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-4);
        for (x, y, nv) in [
            (0.1, 0.02, 0.0),
            (0.3, -0.03, 0.0),
            (0.5, 5.0, 25.0), // outlier, sigma_obs = 5
            (0.7, 0.01, 0.0),
            (0.9, -0.02, 0.0),
        ] {
            gp.add_sample_noisy(&[x], y, nv);
        }
        assert!(gp.has_noisy_observations());
        let best_mu = gp.best_predicted_mean().unwrap();
        assert!(
            best_mu < 1.0,
            "posterior should discount the noisy outlier: {best_mu}"
        );

        // even when the context carries the raw-max incumbent (5.0), the
        // threshold must be the predicted mean under noise
        let ctx = AcquiContext::new(3, 5.0, 1);
        let thr = incumbent_for(&gp, &ctx, &[0.0]);
        assert_eq!(thr.to_bits(), best_mu.to_bits());

        // consequence: EI near clean points stays alive instead of being
        // flattened by an unreachable noise-made threshold
        let ei = Ei { xi: 0.0 };
        let v = ei.eval(&gp, &[2.0], &ctx);
        assert!(v > 1e-6, "EI under noise should not be dead: {v}");

        // a noise-free model is untouched: same context keeps ctx.best
        let mut clean = Gp::new(SquaredExpArd::new(1), ZeroMean, 1e-4);
        clean.fit(&[vec![0.2], vec![0.8]], &[1.0, -1.0]);
        assert_eq!(incumbent_for(&clean, &ctx, &[0.0]).to_bits(), 5.0f64.to_bits());
    }

    #[test]
    fn ei_nonnegative_and_monotone_across_the_tails() {
        // sweep z in [-10, 10]: EI(mu = z, sigma = 1, thr = 0) must stay
        // nonnegative (the A&S erf approximation can otherwise dip to
        // ~-2e-16 in the far left tail) and monotone in mu up to the
        // approximation's noise floor
        let ei = Ei { xi: 0.0 };
        let mut prev = -1.0;
        for i in 0..=2000 {
            let z = -10.0 + i as f64 * 0.01;
            let v = ei.score(z, 1.0, 0.0);
            assert!(v >= 0.0, "EI(z={z}) = {v} < 0");
            assert!(
                v >= prev - 1e-12,
                "EI not monotone at z={z}: {v} < prev {prev}"
            );
            prev = v;
        }
        // deep left tail is vanishingly small (clamped at 0, never below)
        assert!(ei.score(-10.0, 1.0, 0.0) < 1e-20);
        // the A&S dip region (z ~ -8.4 drives the closed form slightly
        // negative) must come out exactly clamped
        assert!(ei.score(-8.375, 1.0, 0.0) >= 0.0);
        // right tail approaches the gain asymptote
        assert!((ei.score(10.0, 1.0, 0.0) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn eval_batch_matches_pointwise_for_all_acquisitions() {
        let gp = fitted_gp();
        let ctx = AcquiContext::new(3, 0.5, 1);
        let cands: Vec<Vec<f64>> =
            (0..9).map(|i| vec![i as f64 / 8.0]).collect();
        let acquis: Vec<Box<dyn AcquiFn<Gp<SquaredExpArd, ZeroMean>>>> = vec![
            Box::new(Ucb::default()),
            Box::new(GpUcb::default()),
            Box::new(Ei::default()),
            Box::new(Pi::default()),
        ];
        for a in &acquis {
            let batch = a.eval_batch(&gp, &cands, &ctx);
            assert_eq!(batch.len(), cands.len());
            for (j, c) in cands.iter().enumerate() {
                let v = a.eval(&gp, c, &ctx);
                assert!((batch[j] - v).abs() < 1e-10, "batch[{j}]={} vs {v}", batch[j]);
            }
        }
    }

    #[test]
    fn acqui_objective_routes_eval_many_through_batch() {
        let gp = fitted_gp();
        let acq = Ucb::default();
        let obj = AcquiObjective::new(&gp, &acq, AcquiContext::new(0, 1.0, 1));
        let cands = vec![vec![0.1], vec![0.9]];
        let many = obj.eval_many(&cands);
        assert!((many[0] - obj.eval(&cands[0])).abs() < 1e-12);
        assert!((many[1] - obj.eval(&cands[1])).abs() < 1e-12);
    }

    #[test]
    fn gp_ucb_beta_matches_direct_formula() {
        // the precomputed split must reproduce the textbook schedule
        let a = GpUcb { delta: 0.17 };
        for (it, dim) in [(0usize, 1usize), (4, 2), (99, 6)] {
            let ctx = AcquiContext::new(it, 0.0, dim);
            let t = (it + 1) as f64;
            let d = dim as f64;
            let direct = (2.0
                * (t.powf(d / 2.0 + 2.0) * std::f64::consts::PI.powi(2) / (3.0 * 0.17))
                    .ln())
            .max(0.0)
            .sqrt();
            assert!((a.beta(&ctx) - direct).abs() < 1e-9, "it={it} dim={dim}");
        }
    }
}
