//! Prior-mean functions — the `limbo::mean::*` policy family.
//!
//! [`DataMean`] (the running mean of the observations, Limbo's
//! `mean::Data`) is the default; [`MeanFn::update`] is called by the GP on
//! every refit so data-dependent means stay current.

/// A prior mean function `m(x)` for the GP.
pub trait MeanFn: Clone + Send + Sync + 'static {
    /// Evaluate the prior mean at `x`.
    fn eval(&self, x: &[f64]) -> f64;

    /// Refresh any data-dependent state from the current observations.
    fn update(&mut self, _ys: &[f64]) {}
}

/// Zero prior mean.
#[derive(Clone, Debug, Default)]
pub struct ZeroMean;

impl MeanFn for ZeroMean {
    fn eval(&self, _x: &[f64]) -> f64 {
        0.0
    }
}

/// Constant prior mean.
#[derive(Clone, Debug)]
pub struct ConstantMean(pub f64);

impl MeanFn for ConstantMean {
    fn eval(&self, _x: &[f64]) -> f64 {
        self.0
    }
}

/// Mean of the observations (Limbo's `mean::Data`, recomputed on update).
#[derive(Clone, Debug, Default)]
pub struct DataMean {
    value: f64,
}

impl MeanFn for DataMean {
    fn eval(&self, _x: &[f64]) -> f64 {
        self.value
    }

    fn update(&mut self, ys: &[f64]) {
        self.value = if ys.is_empty() { 0.0 } else { ys.iter().sum::<f64>() / ys.len() as f64 };
    }
}

/// A user-supplied mean function (Limbo's `mean::FunctionARD` analogue,
/// without the tunable transform).
#[derive(Clone)]
pub struct FunctionMean<F: Fn(&[f64]) -> f64 + Clone + Send + Sync + 'static>(pub F);

impl<F: Fn(&[f64]) -> f64 + Clone + Send + Sync + 'static> MeanFn for FunctionMean<F> {
    fn eval(&self, x: &[f64]) -> f64 {
        (self.0)(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_and_constant() {
        assert_eq!(ZeroMean.eval(&[1.0]), 0.0);
        assert_eq!(ConstantMean(3.5).eval(&[1.0]), 3.5);
    }

    #[test]
    fn data_mean_tracks_observations() {
        let mut m = DataMean::default();
        assert_eq!(m.eval(&[0.0]), 0.0);
        m.update(&[1.0, 2.0, 3.0]);
        assert_eq!(m.eval(&[0.0]), 2.0);
        m.update(&[]);
        assert_eq!(m.eval(&[0.0]), 0.0);
    }

    #[test]
    fn function_mean_evaluates() {
        let m = FunctionMean(|x: &[f64]| x[0] * 2.0);
        assert_eq!(m.eval(&[1.5]), 3.0);
    }
}
