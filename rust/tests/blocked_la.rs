//! Property tests for the blocked/threaded dense kernels in `limbo::la`.
//!
//! Two families of guarantees, matching the contract documented in
//! `la::tune`:
//!
//! * **parity** — the blocked code paths agree with scalar references to
//!   `<= 1e-12` across awkward sizes (1, block-1, block, block+1,
//!   non-square), because `block`/`small` may legitimately change the
//!   floating-point summation order;
//! * **bit-stability** — `threads` (and `par_min_flops`) NEVER change a
//!   result bitwise, because parallelism only splits disjoint output
//!   panels whose per-element arithmetic is fixed.
//!
//! The global [`limbo::la::Tune`] is process-wide, so every test that
//! overrides it goes through [`with_tune`], which serializes on a mutex
//! and restores the prior configuration even on panic.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use limbo::kernel::{Kernel, Matern52};
use limbo::la::{set_tune, tune, CholeskyFactor, Matrix, Tune};
use limbo::rng::Pcg64;

static TUNE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the global la tuning set to `t`, restoring the previous
/// configuration afterwards (also on panic, so one failing test does not
/// poison the others).
fn with_tune<R>(t: Tune, f: impl FnOnce() -> R) -> R {
    let _guard = TUNE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prior = tune();
    set_tune(t);
    let out = catch_unwind(AssertUnwindSafe(f));
    set_tune(prior);
    match out {
        Ok(r) => r,
        Err(p) => resume_unwind(p),
    }
}

/// A tuning that forces the blocked + threaded paths regardless of size.
fn forced(threads: usize, block: usize) -> Tune {
    Tune { threads, block, small: 0, par_min_flops: 0 }
}

fn random_matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| rng.uniform(-1.0, 1.0))
}

/// SPD test matrix: `B Bᵀ + n·I` (well conditioned at every size).
fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
    let b = random_matrix(rng, n, n);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += b[(i, k)] * b[(j, k)];
            }
            a[(i, j)] = s;
        }
        a[(i, i)] += n as f64;
    }
    a
}

/// Scalar ikj reference product (the order the blocked kernel preserves).
fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (n, k, m) = (a.rows(), b.rows(), b.cols());
    let mut out = Matrix::zeros(n, m);
    for i in 0..n {
        for kk in 0..k {
            let av = a[(i, kk)];
            if av == 0.0 {
                continue;
            }
            for j in 0..m {
                out[(i, j)] += av * b[(kk, j)];
            }
        }
    }
    out
}

#[test]
fn blocked_matmul_matches_naive_across_odd_shapes() {
    // (n, k, m): 1, block-1, block, block+1, and non-square mixes for a
    // forced block of 32.
    let shapes = [
        (1, 1, 1),
        (7, 8, 9),
        (31, 32, 33),
        (32, 32, 32),
        (33, 31, 65),
        (64, 64, 64),
        (65, 64, 63),
        (100, 20, 5),
    ];
    let mut rng = Pcg64::seed(0xB10C);
    for &(n, k, m) in &shapes {
        let a = random_matrix(&mut rng, n, k);
        let b = random_matrix(&mut rng, k, m);
        let want = naive_matmul(&a, &b);
        for t in [forced(8, 32), forced(3, 5)] {
            let got = with_tune(t, || a.matmul(&b));
            let diff = got.max_abs_diff(&want);
            assert!(diff <= 1e-12, "matmul ({n}x{k})·({k}x{m}) t={t:?}: diff={diff:e}");
        }
    }
}

#[test]
fn blocked_col_gram_matches_naive() {
    let mut rng = Pcg64::seed(0xC0DE);
    for &(rows, m) in &[(1usize, 1usize), (9, 7), (40, 31), (33, 32), (50, 33), (20, 65)] {
        let a = random_matrix(&mut rng, rows, m);
        let mut want = Matrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                let mut s = 0.0;
                for r in 0..rows {
                    s += a[(r, i)] * a[(r, j)];
                }
                want[(i, j)] = s;
            }
        }
        let got = with_tune(forced(8, 16), || a.col_gram());
        let diff = got.max_abs_diff(&want);
        assert!(diff <= 1e-12, "col_gram {rows}x{m}: diff={diff:e}");
        // the diagonal contract used by lowrank code: g[(j,j)] equals the
        // column norm bitwise
        let norms = a.col_squared_norms();
        for j in 0..m {
            assert_eq!(got[(j, j)].to_bits(), norms[j].to_bits(), "diag {j}");
        }
    }
}

#[test]
fn blocked_cholesky_matches_unblocked_across_odd_sizes() {
    let mut rng = Pcg64::seed(0x50D);
    // forced block of 8: covers below/at/above the panel width and sizes
    // with ragged trailing panels
    for &n in &[1usize, 7, 8, 9, 31, 32, 33, 65, 130] {
        let a = random_spd(&mut rng, n);
        let want = CholeskyFactor::factor_unblocked(&a).expect("spd");
        let got = with_tune(forced(8, 8), || CholeskyFactor::factor(&a).expect("spd"));
        let diff = got.l().max_abs_diff(want.l());
        assert!(diff <= 1e-12, "cholesky n={n}: diff={diff:e}");
    }
}

#[test]
fn multi_rhs_solves_match_per_column_references() {
    let mut rng = Pcg64::seed(0xABCD);
    for &(n, m) in &[(5usize, 1usize), (20, 63), (33, 64), (40, 65), (16, 130)] {
        let a = random_spd(&mut rng, n);
        let b = random_matrix(&mut rng, n, m);
        let chol = CholeskyFactor::factor_unblocked(&a).expect("spd");
        let (lo, lot, full) = with_tune(forced(8, 16), || {
            (chol.solve_lower_multi(&b), chol.solve_lower_t_multi(&b), chol.solve_multi(&b))
        });
        for j in 0..m {
            let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
            let r_lo = chol.solve_lower(&col);
            let r_lot = chol.solve_lower_t(&col);
            let r_full = chol.solve(&col);
            for i in 0..n {
                assert!((lo[(i, j)] - r_lo[i]).abs() <= 1e-12, "solve_lower n={n} m={m}");
                assert!((lot[(i, j)] - r_lot[i]).abs() <= 1e-12, "solve_lower_t n={n} m={m}");
                assert!((full[(i, j)] - r_full[i]).abs() <= 1e-12, "solve n={n} m={m}");
            }
        }
    }
}

#[test]
fn cross_cov_and_grad_block_match_pairwise_references() {
    let mut rng = Pcg64::seed(0xFACE);
    let dim = 3;
    let k = Matern52::new(dim);
    let xs: Vec<Vec<f64>> = (0..140).map(|_| rng.unit_point(dim)).collect();
    let cands: Vec<Vec<f64>> = (0..70).map(|_| rng.unit_point(dim)).collect();

    let cov = with_tune(forced(8, 16), || k.cross_cov(&xs, &cands));
    let mut max_diff: f64 = 0.0;
    for (i, a) in xs.iter().enumerate() {
        for (j, b) in cands.iter().enumerate() {
            max_diff = max_diff.max((cov[(i, j)] - k.eval(a, b)).abs());
        }
    }
    assert!(max_diff <= 1e-12, "cross_cov vs eval: diff={max_diff:e}");

    let w = random_matrix(&mut rng, xs.len(), cands.len());
    let np = k.n_params();
    let mut got = vec![0.0; np];
    with_tune(forced(8, 16), || k.grad_params_block(&xs, &cands, &w, &mut got));
    let mut want = vec![0.0; np];
    let mut tmp = vec![0.0; np];
    for (i, a) in xs.iter().enumerate() {
        for (j, b) in cands.iter().enumerate() {
            k.grad_params(a, b, &mut tmp);
            for (acc, g) in want.iter_mut().zip(&tmp) {
                *acc += w[(i, j)] * g;
            }
        }
    }
    for p in 0..np {
        let rel = (got[p] - want[p]).abs() / (1.0 + want[p].abs());
        assert!(rel <= 1e-9, "grad_params_block param {p}: {} vs {}", got[p], want[p]);
    }
}

#[test]
fn thread_count_never_changes_results_bitwise() {
    let mut rng = Pcg64::seed(0x7EAD);
    let a = random_spd(&mut rng, 96);
    let b = random_matrix(&mut rng, 96, 40);
    let dim = 3;
    let kern = Matern52::new(dim);
    let xs: Vec<Vec<f64>> = (0..96).map(|_| rng.unit_point(dim)).collect();
    let cands: Vec<Vec<f64>> = (0..40).map(|_| rng.unit_point(dim)).collect();
    let w = random_matrix(&mut rng, xs.len(), cands.len());

    // full pipeline under each thread count: factor, multi-solve, matmul,
    // cross-covariance, and the gradient contraction
    let run = |threads: usize| {
        with_tune(forced(threads, 16), || {
            let chol = CholeskyFactor::factor(&a).expect("spd");
            let x = chol.solve_lower_multi(&b);
            let c = a.matmul(&b);
            let cov = kern.cross_cov(&xs, &cands);
            let mut grad = vec![0.0; kern.n_params()];
            kern.grad_params_block(&xs, &cands, &w, &mut grad);
            let mut bits: Vec<u64> = Vec::new();
            bits.extend(chol.l().data().iter().map(|v| v.to_bits()));
            bits.extend(x.data().iter().map(|v| v.to_bits()));
            bits.extend(c.data().iter().map(|v| v.to_bits()));
            bits.extend(cov.data().iter().map(|v| v.to_bits()));
            bits.extend(grad.iter().map(|v| v.to_bits()));
            bits
        })
    };

    let base = run(1);
    for threads in [2, 8] {
        let other = run(threads);
        assert_eq!(base, other, "threads={threads} changed a result bitwise");
    }
}
