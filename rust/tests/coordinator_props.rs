//! Property tests on coordinator invariants: tier routing, candidate
//! batching, mask-padding exactness (fuzzed from the Rust side), ask/tell
//! state, config round-trips, and experiment aggregation. Uses the
//! in-crate randomized `testing::check` driver (proptest is unavailable
//! offline); XLA-dependent properties skip when artifacts are absent.

use std::sync::Arc;

use limbo::benchlib::Summary;
use limbo::coordinator::config::Config;
use limbo::coordinator::multiobj::Archive;
use limbo::coordinator::xla_model::XlaGpModel;
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::rng::Pcg64;
use limbo::runtime::{find_artifact_dir, Registry, RtClient, XlaGp};
use limbo::testing;

#[test]
fn tier_routing_picks_minimal_sufficient_tier() {
    let Some(dir) = find_artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let reg = Registry::load(&dir).unwrap();
    testing::check(
        "tier-routing",
        0x7162,
        128,
        |rng: &mut Pcg64| 1 + rng.below(300),
        |&n| {
            let tiers = reg.tiers("predict", "matern52");
            let chosen = reg.tier_for("predict", "matern52", n);
            match chosen {
                Some(meta) => {
                    if meta.n_max < n {
                        return Err(format!("tier {} cannot hold {n}", meta.n_max));
                    }
                    // minimality: no smaller tier also fits
                    for t in tiers {
                        if t.n_max >= n && t.n_max < meta.n_max {
                            return Err(format!(
                                "tier {} chosen but {} suffices",
                                meta.n_max, t.n_max
                            ));
                        }
                    }
                    Ok(())
                }
                None => {
                    let max = tiers.iter().map(|t| t.n_max).max().unwrap_or(0);
                    if n <= max {
                        Err(format!("no tier for {n} but max is {max}"))
                    } else {
                        Ok(())
                    }
                }
            }
        },
    );
}

#[test]
fn batching_is_chunk_invariant() {
    // predictions must not depend on how candidates are split into blocks
    let Some(dir) = find_artifact_dir() else {
        return;
    };
    let client = Arc::new(RtClient::cpu().unwrap());
    let backend = Arc::new(XlaGp::new(client, &dir, "matern52").unwrap());
    let mut rng = Pcg64::seed(0xBA7C);
    let xs: Vec<Vec<f64>> = (0..15).map(|_| rng.unit_point(2)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x[0] * x[1]).collect();
    let mut model = XlaGpModel::new(backend, 2);
    model.fit(&xs, &ys);

    // 100 candidates -> chunks of 64 + 36; compare against per-point
    let cands: Vec<Vec<f64>> = (0..100).map(|_| rng.unit_point(2)).collect();
    let batched = model.predict_batch(&cands);
    for (i, c) in cands.iter().enumerate() {
        let (mu, var) = model.predict(c);
        testing::close(batched[i].0, mu, 1e-5)
            .map_err(|e| format!("mu[{i}]: {e}"))
            .unwrap();
        testing::close(batched[i].1, var, 1e-5)
            .map_err(|e| format!("var[{i}]: {e}"))
            .unwrap();
    }
}

#[test]
fn padding_is_exact_across_random_dataset_sizes() {
    // fuzz the mask-padding contract: XLA result must track the native GP
    // (same hyper-params) for any dataset size within the top tier
    let Some(dir) = find_artifact_dir() else {
        return;
    };
    let client = Arc::new(RtClient::cpu().unwrap());
    let backend = Arc::new(XlaGp::new(client, &dir, "matern52").unwrap());
    testing::check(
        "padding-exactness",
        0xBEE5,
        12,
        |rng: &mut Pcg64| {
            let n = 2 + rng.below(70);
            let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
            let ys: Vec<f64> = xs.iter().map(|x| (3.0 * x[0]).sin() - x[1]).collect();
            let probe = rng.unit_point(2);
            (xs, ys, probe)
        },
        |(xs, ys, probe)| {
            let mut native = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
            native.fit(xs, ys);
            let mut xla = XlaGpModel::new(backend.clone(), 2);
            xla.loghp = native.xla_loghp();
            xla.fit(xs, ys);
            let (mn, vn) = native.predict(probe);
            let (mx, vx) = xla.predict(probe);
            testing::close(mn, mx, 5e-3)?;
            testing::close(vn, vx, 5e-3)
        },
    );
}

#[test]
fn ask_tell_state_is_consistent() {
    use limbo::bayes_opt::BoDef;
    use limbo::opt::RandomPoint;
    testing::check(
        "ask-tell-state",
        0xA5C,
        16,
        |rng: &mut Pcg64| (1 + rng.below(3), 3 + rng.below(10), rng.next_u64()),
        |&(dim, steps, seed)| {
            let mut srv =
                BoDef::service(dim).seed(seed).inner_opt(RandomPoint::new(32)).build_server();
            let mut true_best = f64::NEG_INFINITY;
            for i in 0..steps {
                let x = srv.ask();
                if x.len() != dim || x.iter().any(|&v| !(0.0..=1.0).contains(&v)) {
                    return Err(format!("ask returned invalid point {x:?}"));
                }
                let y = -(i as f64 - 3.0).abs(); // deterministic outcomes
                srv.tell(&x, y);
                true_best = true_best.max(y);
            }
            match srv.best() {
                Some((_, v)) if (v - true_best).abs() < 1e-15 => Ok(()),
                other => Err(format!("best {:?} != {true_best}", other.map(|b| b.1))),
            }
        },
    );
}

#[test]
fn summary_quantiles_are_order_statistics() {
    testing::check(
        "summary-props",
        0x5A11,
        64,
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(40);
            (0..n).map(|_| rng.uniform(-10.0, 10.0)).collect::<Vec<f64>>()
        },
        |samples| {
            let s = Summary::from(samples);
            if !(s.min <= s.q1 && s.q1 <= s.median && s.median <= s.q3 && s.q3 <= s.max) {
                return Err(format!("quantiles out of order: {s:?}"));
            }
            if s.min < samples.iter().cloned().fold(f64::INFINITY, f64::min) - 1e-12 {
                return Err("min below sample min".into());
            }
            if s.std < 0.0 {
                return Err("negative std".into());
            }
            // median is permutation invariant
            let mut rev = samples.clone();
            rev.reverse();
            testing::close(Summary::from(&rev).median, s.median, 1e-12)
        },
    );
}

#[test]
fn config_roundtrip_fuzz() {
    testing::check(
        "config-roundtrip",
        0xC0F,
        64,
        |rng: &mut Pcg64| {
            let n = rng.below(6);
            (0..n)
                .map(|i| (format!("key{i}"), rng.below(1000)))
                .collect::<Vec<(String, usize)>>()
        },
        |pairs| {
            let text: String =
                pairs.iter().map(|(k, v)| format!("{k} = {v}\n")).collect();
            let cfg = Config::parse(&text).map_err(|e| e)?;
            for (k, v) in pairs {
                if cfg.get_usize(k, usize::MAX) != *v {
                    return Err(format!("lost {k}={v}"));
                }
            }
            if cfg.len() != pairs.len() {
                return Err("length mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn pareto_archive_is_always_nondominated() {
    testing::check(
        "pareto-invariant",
        0xFA12,
        32,
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(30);
            (0..n)
                .map(|_| vec![rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)])
                .collect::<Vec<Vec<f64>>>()
        },
        |points| {
            let mut archive = Archive::default();
            for (i, p) in points.iter().enumerate() {
                archive.insert(vec![i as f64], p.clone());
            }
            let front = archive.front();
            // pairwise non-domination
            for (i, (_, a)) in front.iter().enumerate() {
                for (j, (_, b)) in front.iter().enumerate() {
                    if i != j && Archive::dominates(a, b) {
                        return Err(format!("front contains dominated pair {a:?} > {b:?}"));
                    }
                }
            }
            // every input is dominated-by-or-equal-to something on the front
            for p in points {
                let covered = front
                    .iter()
                    .any(|(_, f)| f == p || Archive::dominates(f, p));
                if !covered {
                    return Err(format!("point {p:?} missing from front closure"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gp_state_roundtrip_fuzz() {
    use limbo::model::GpState;
    testing::check(
        "gp-state-roundtrip",
        0x5E12DE,
        24,
        |rng: &mut Pcg64| {
            let dim = 1 + rng.below(4);
            let n = 1 + rng.below(12);
            let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
            let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (dim, xs, ys)
        },
        |(dim, xs, ys)| {
            let mut gp = Gp::new(Matern52::new(*dim), DataMean::default(), 0.05);
            gp.fit(xs, ys);
            let text = GpState::capture(&gp).to_text();
            let state = GpState::from_text(&text).map_err(|e| e)?;
            let mut gp2 = Gp::new(Matern52::new(*dim), DataMean::default(), 0.2);
            state.restore(&mut gp2)?;
            let probe = vec![0.4; *dim];
            let (m1, v1) = gp.predict(&probe);
            let (m2, v2) = gp2.predict(&probe);
            testing::close(m1, m2, 1e-10)?;
            testing::close(v1, v2, 1e-10)
        },
    );
}
