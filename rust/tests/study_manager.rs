//! Integration: the multi-study [`StudyManager`] at scale and across
//! "process" boundaries.
//!
//! Three claims from the manager's contract:
//!
//! 1. **Interleaving is invisible.** ≥1000 studies driven round-robin
//!    through one manager (with LRU eviction churn forcing constant
//!    rehydration) produce traces **bit-identical** to each study run
//!    in isolation through the plain `BoDef::build_server` frontend.
//! 2. **Crashes are invisible.** A durable study killed mid-run (the
//!    manager dropped without `close`) and rehydrated by a fresh
//!    manager from its snapshot + event-log tail continues the exact
//!    trace of an uninterrupted run — byte-identical event logs.
//! 3. **Deployment mode is invisible.** The same definition driven
//!    through `&mut dyn Study` — inline server, spawned thread,
//!    managed study — yields bit-identical traces.

use std::fs;
use std::path::PathBuf;
use std::sync::Arc;

use limbo::bayes_opt::{BoDef, Observation, RefitSchedule};
use limbo::coordinator::{Study, StudyError, StudyManager};
use limbo::opt::RandomPoint;
use limbo::pool::ThreadPool;

fn pool(threads: usize) -> Arc<ThreadPool> {
    Arc::new(ThreadPool::new(threads))
}

fn tmp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Deterministic 1-D objective, different optimum per study.
fn objective(study: usize, x: &[f64]) -> f64 {
    let target = (study % 97) as f64 / 96.0;
    -(x[0] - target).powi(2)
}

fn bits(xs: &[Vec<f64>]) -> Vec<Vec<u64>> {
    xs.iter().map(|x| x.iter().map(|v| v.to_bits()).collect()).collect()
}

#[test]
fn a_thousand_interleaved_studies_match_isolated_runs_bitwise() {
    const STUDIES: usize = 1000;
    const ROUNDS: usize = 3;
    let root = tmp_root("limbo_mgr_thousand");
    // max_live far below the study count: most operations hit an
    // evicted slot and must rehydrate by replaying the event log
    let mgr = StudyManager::durable(pool(4), &root).expect("durable root").with_max_live(64);
    let ids: Vec<_> = (0..STUDIES)
        .map(|s| {
            let seed = 1000 + s as u64;
            mgr.create(move || {
                BoDef::service(1).seed(seed).inner_opt(RandomPoint::new(8)).build_server()
            })
            .expect("create study")
        })
        .collect();
    let (live, evicted) = mgr.counts();
    assert_eq!(live + evicted, STUDIES);
    assert!(live <= 64, "live budget violated: {live}");

    // drive all studies round-robin: maximal interleaving, every study's
    // operations separated by ~999 other studies' operations
    let mut traces: Vec<Vec<Vec<f64>>> = vec![Vec::new(); STUDIES];
    for _round in 0..ROUNDS {
        for (s, &id) in ids.iter().enumerate() {
            let x = mgr.ask(id).expect("ask");
            let y = objective(s, &x);
            mgr.tell(id, &x, y).expect("tell");
            traces[s].push(x);
        }
    }
    let (live, _) = mgr.counts();
    assert!(live <= 64, "live budget violated after churn: {live}");

    // parity: each study in isolation, straight through the frontend
    for (s, trace) in traces.iter().enumerate() {
        let seed = 1000 + s as u64;
        let mut iso = BoDef::service(1).seed(seed).inner_opt(RandomPoint::new(8)).build_server();
        for expected in trace {
            let x = iso.ask();
            assert_eq!(
                bits(std::slice::from_ref(&x)),
                bits(std::slice::from_ref(expected)),
                "study {s}: interleaved trace diverged from the isolated run"
            );
            iso.tell(&x, objective(s, &x));
        }
    }
    let _ = fs::remove_dir_all(&root);
}

/// Drive `rounds` ask/tell rounds against study 0 of `mgr`.
fn drive(mgr: &StudyManager, id: limbo::coordinator::StudyId, rounds: usize) {
    for _ in 0..rounds {
        let x = mgr.ask(id).expect("ask");
        let y = objective(0, &x);
        mgr.tell(id, &x, y).expect("tell");
    }
}

#[test]
fn killed_study_resumes_the_exact_trace_from_snapshot_and_log_tail() {
    let factory = || {
        BoDef::service(1)
            .seed(77)
            .inner_opt(RandomPoint::new(8))
            // early refits so a refit-barrier snapshot lands before the
            // "crash" and the recovery exercises snapshot + tail replay
            .refit(RefitSchedule::Doubling { first: 4 })
            .build_server()
    };

    // reference: 12 uninterrupted rounds
    let root_a = tmp_root("limbo_mgr_crash_a");
    {
        let mgr = StudyManager::durable(pool(2), &root_a).expect("durable");
        let id = mgr.create(factory).expect("create");
        drive(&mgr, id, 12);
        // manager dropped without close: Drop flushes the event log
    }

    // crashed: 5 rounds, drop the manager mid-run, recover, 7 more
    let root_b = tmp_root("limbo_mgr_crash_b");
    let id = {
        let mgr = StudyManager::durable(pool(2), &root_b).expect("durable");
        let id = mgr.create(factory).expect("create");
        drive(&mgr, id, 5);
        id
    };
    let snap = root_b.join(id.to_string()).join("snapshot.txt");
    assert!(snap.exists(), "refit at n=4 must have produced a snapshot before the crash");
    {
        let mgr = StudyManager::durable(pool(2), &root_b).expect("durable");
        mgr.recover(id, factory).expect("recover");
        drive(&mgr, id, 7);
    }

    let log_a = fs::read(root_a.join(id.to_string()).join("events.jsonl")).expect("log a");
    let log_b = fs::read(root_b.join(id.to_string()).join("events.jsonl")).expect("log b");
    assert_eq!(
        String::from_utf8_lossy(&log_a),
        String::from_utf8_lossy(&log_b),
        "resumed trace must be byte-identical to the uninterrupted run"
    );
    let _ = fs::remove_dir_all(&root_a);
    let _ = fs::remove_dir_all(&root_b);
}

/// Drive `rounds` noisy **and** constrained rounds against study `id`:
/// every tell carries a deterministic per-observation noise variance and
/// one constraint value, so the event log is all `tell_constrained`
/// records with non-null noise.
fn drive_constrained(mgr: &StudyManager, id: limbo::coordinator::StudyId, rounds: usize) {
    for _ in 0..rounds {
        let x = mgr.ask(id).expect("ask");
        let y = objective(0, &x);
        let noise = 0.05 + 0.01 * x[0];
        let c = 0.3 - (x[0] - 0.5).abs();
        let obs = Observation::noisy(x, y, noise).with_constraints(vec![c]);
        mgr.tell_observation(id, obs).expect("tell_observation");
    }
}

#[test]
fn killed_noisy_constrained_study_recovers_to_a_byte_identical_log() {
    let factory = || {
        BoDef::service(1)
            .seed(91)
            .inner_opt(RandomPoint::new(8))
            .refit(RefitSchedule::Doubling { first: 4 })
            .constraints(1)
            .build_constrained_server()
    };

    // reference: 12 uninterrupted noisy + constrained rounds
    let root_a = tmp_root("limbo_mgr_crash_bank_a");
    {
        let mgr = StudyManager::durable(pool(2), &root_a).expect("durable");
        let id = mgr.create(factory).expect("create");
        drive_constrained(&mgr, id, 12);
    }

    // crashed: 5 rounds, drop the manager mid-run, recover, 7 more. The
    // refit at n = 4 snapshots the full model bank (objective GP + one
    // constraint GP) plus per-observation noise, so recovery exercises
    // the generalized snapshot + the tell_constrained replay arm.
    let root_b = tmp_root("limbo_mgr_crash_bank_b");
    let id = {
        let mgr = StudyManager::durable(pool(2), &root_b).expect("durable");
        let id = mgr.create(factory).expect("create");
        drive_constrained(&mgr, id, 5);
        id
    };
    let snap = root_b.join(id.to_string()).join("snapshot.txt");
    assert!(snap.exists(), "refit at n=4 must have produced a snapshot before the crash");
    {
        let mgr = StudyManager::durable(pool(2), &root_b).expect("durable");
        mgr.recover(id, factory).expect("recover");
        drive_constrained(&mgr, id, 7);
    }

    let log_a = fs::read(root_a.join(id.to_string()).join("events.jsonl")).expect("log a");
    let log_b = fs::read(root_b.join(id.to_string()).join("events.jsonl")).expect("log b");
    assert_eq!(
        String::from_utf8_lossy(&log_a),
        String::from_utf8_lossy(&log_b),
        "resumed noisy+constrained trace must be byte-identical to the uninterrupted run"
    );
    let _ = fs::remove_dir_all(&root_a);
    let _ = fs::remove_dir_all(&root_b);
}

/// The shared driver: everything it needs is the [`Study`] vocabulary.
fn drive_study(study: &mut dyn Study, rounds: usize) -> Vec<Vec<f64>> {
    let mut trace = Vec::new();
    for _ in 0..rounds {
        let x = study.ask().expect("ask");
        let y = objective(3, &x);
        study.tell(&x, y).expect("tell");
        trace.push(x);
    }
    assert!(study.best().expect("best").is_some(), "data recorded, best must exist");
    trace
}

#[test]
fn study_trait_erases_the_deployment_mode() {
    let def = || BoDef::service(1).seed(5).inner_opt(RandomPoint::new(16)).build_server();

    // (a) inline server
    let mut inline = def();
    let trace_inline = drive_study(&mut inline, 6);

    // (b) spawned server behind its channel handle
    let mut handle = def().spawn();
    let trace_handle = drive_study(&mut handle, 6);
    handle.finish().expect("first finish shuts the server down");
    assert_eq!(
        handle.try_ask(),
        Err(StudyError::Closed),
        "operations after shutdown report Closed"
    );

    // (c) managed study in a registry
    let mgr = Arc::new(StudyManager::new(pool(2)));
    let id = mgr.create(def).expect("create");
    let mut managed = mgr.study(id);
    let trace_managed = drive_study(&mut managed, 6);
    managed.finish().expect("close");
    assert_eq!(managed.ask(), Err(StudyError::Closed));

    assert_eq!(bits(&trace_inline), bits(&trace_handle), "inline vs threaded trace");
    assert_eq!(bits(&trace_inline), bits(&trace_managed), "inline vs managed trace");
}
