//! Integration: joint-posterior qEI batch proposals vs the constant liar
//! on Branin at q = 4 with an equal evaluation budget.
//!
//! Both servers share the same surrogate family (dense GP, Matérn-5/2,
//! data mean), the same EI-family acquisition, the same inner optimizer
//! budget, the same ML-II refit schedule, and the same per-seed init
//! design; only the batch strategy differs. Regret is aggregated over a
//! few seeds — a single-seed comparison of two stochastic optimizers is
//! a coin flip, the aggregate is the claim qEI makes (and the MC slack
//! below covers estimator noise, ~1/sqrt(mc_samples)).

use limbo::acqui::Ei;
use limbo::bayes_opt::{BoDef, RefitSchedule};
use limbo::benchfns::{Branin, TestFunction};
use limbo::coordinator::BatchStrategy;
use limbo::opt::{NelderMead, OptimizerExt, RandomPoint};
use limbo::rng::Pcg64;

const Q: usize = 4;
const ROUNDS: usize = 9;
const N_INIT: usize = 6;

/// One full batched BO run on Branin; returns the simple regret.
fn run_branin(strategy: BatchStrategy, seed: u64) -> f64 {
    let branin = Branin;
    let mut srv = BoDef::service(2)
        .noise(1e-2)
        .acquisition(Ei::default())
        .inner_opt(RandomPoint::new(128).then(NelderMead::default()).restarts(4, 2))
        .seed(seed)
        .refit(RefitSchedule::Doubling { first: 8 })
        .batch(strategy)
        .build_server();
    // shared init design per seed (identical across strategies)
    let mut init_rng = Pcg64::seed(seed ^ 0xB0A71);
    for _ in 0..N_INIT {
        let x = init_rng.unit_point(2);
        let y = branin.eval(&x);
        srv.tell(&x, y);
    }
    for _ in 0..ROUNDS {
        for x in srv.ask_batch(Q) {
            let y = branin.eval(&x);
            srv.tell(&x, y);
        }
    }
    let (_, best) = srv.best().expect("observations recorded");
    branin.optimum() - best
}

#[test]
fn qei_regret_at_most_constant_liar_on_branin_q4() {
    let seeds = [101u64, 202, 303];
    let mut cl_total = 0.0;
    let mut qei_total = 0.0;
    for &seed in &seeds {
        let cl = run_branin(BatchStrategy::ConstantLiar, seed);
        let qei = run_branin(BatchStrategy::QEi { mc_samples: 512 }, seed);
        println!("seed {seed}: CL regret {cl:.4}, qEI regret {qei:.4}");
        cl_total += cl;
        qei_total += qei;
    }
    let cl_mean = cl_total / seeds.len() as f64;
    let qei_mean = qei_total / seeds.len() as f64;
    // 42 evaluations is enough budget for both strategies to converge on
    // Branin; both regrets must be small in absolute terms...
    assert!(cl_mean < 0.5, "constant liar failed to converge: {cl_mean}");
    assert!(qei_mean < 0.5, "qEI failed to converge: {qei_mean}");
    // ...and qEI must be at least as good as the constant liar up to the
    // MC-estimator noise allowance
    assert!(
        qei_mean <= cl_mean + 0.1,
        "qEI mean regret {qei_mean} worse than constant liar {cl_mean} beyond MC slack"
    );
}
