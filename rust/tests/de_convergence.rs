//! Convergence and record/replay coverage for the self-adaptive DE
//! inner-optimizer subsystem (`limbo::opt::de`).
//!
//! Three claims are pinned here:
//!
//! * **Convergence** — `AdaptiveDe` reaches known accuracy bounds on
//!   Branin (2-D), Hartmann-6 and 10-D Ackley at fixed evaluation
//!   budgets, and on the deceptive 10-D Schwefel it matches or beats
//!   DIRECT at an equal budget (the "DE for high-dimensional multimodal
//!   landscapes" claim, on the raw functions).
//! * **Seeding** — `optimize_from` keeps an already-optimal seed point,
//!   including through the `restarts` combinator.
//! * **Record/replay** — a [`RecordingObserver`] capture of a full
//!   DE-driven Branin run replays bit-identically through a fresh
//!   identically-configured server, and survives a save/load round-trip
//!   through the JSONL line format without losing a bit.

use limbo::benchfns::{by_name, Branin};
use limbo::opt::AdaptiveDe;
use limbo::prelude::*;
use limbo::stat::RecordingObserver;

/// One standalone DE run on a named benchmark function; returns the
/// regret (`optimum - best_value`, always `>= 0` up to float error).
fn de_accuracy(func: &str, dim: usize, evals: usize, seed: u64) -> f64 {
    let f = by_name(func, dim).expect("known benchmark function");
    let objective = |x: &[f64]| f.eval(x);
    let mut rng = Pcg64::seed(seed);
    let best = AdaptiveDe::new(evals).optimize(&objective, dim, &mut rng);
    f.accuracy(best.value)
}

#[test]
fn de_converges_on_branin() {
    let acc = de_accuracy("branin", 2, 2000, 11);
    assert!(acc < 1e-2, "branin regret {acc} at 2000 evals");
}

#[test]
fn de_converges_on_hartmann6() {
    let acc = de_accuracy("hartmann6", 6, 4000, 12);
    assert!(acc < 0.2, "hartmann6 regret {acc} at 4000 evals");
}

#[test]
fn de_converges_on_ackley_10d() {
    let acc = de_accuracy("ackley", 10, 6000, 13);
    assert!(acc < 3.0, "ackley-10 regret {acc} at 6000 evals");
}

/// Equal-budget head-to-head on 10-D Schwefel: the optimum sits near
/// the boundary (u ≈ 0.921 per axis) behind deceptive local basins, so
/// center-first trisection has to earn every axis while a population
/// search does not. DE is averaged over seeds against the
/// deterministic DIRECT result.
#[test]
fn de_matches_or_beats_direct_on_schwefel_10d() {
    let f = by_name("schwefel", 10).expect("schwefel");
    let objective = |x: &[f64]| f.eval(x);
    let budget = 4000;
    let direct = Direct::new(budget).optimize(&objective, 10, &mut Pcg64::seed(0));
    let direct_acc = f.accuracy(direct.value);
    let seeds = [21u64, 22, 23];
    let mut de_acc = 0.0;
    for seed in seeds {
        let best = AdaptiveDe::new(budget).optimize(&objective, 10, &mut Pcg64::seed(seed));
        de_acc += f.accuracy(best.value);
    }
    let de_acc = de_acc / seeds.len() as f64;
    assert!(
        de_acc <= direct_acc,
        "DE mean regret {de_acc} worse than DIRECT {direct_acc} at {budget} evals"
    );
    assert!(de_acc < 1500.0, "DE mean regret {de_acc} out of range on schwefel-10");
}

/// `optimize_from` must keep a seed point that is already the optimum:
/// selection only replaces on strict improvement, so the seeded member
/// survives every generation — bare and through `restarts` (which
/// forwards `x0` to every restart).
#[test]
fn optimize_from_keeps_an_optimal_seed_through_restarts() {
    let f = |x: &[f64]| -x.iter().map(|&v| (v - 0.3) * (v - 0.3)).sum::<f64>();
    let x0 = vec![0.3; 4];
    let bare = AdaptiveDe::new(400).optimize_from(&f, &x0, &mut Pcg64::seed(5));
    assert!(bare.value >= 0.0, "bare optimize_from lost the optimal seed: {}", bare.value);
    let de = AdaptiveDe::new(200).restarts(3, 1);
    let restarted = de.optimize_from(&f, &x0, &mut Pcg64::seed(5));
    assert!(
        restarted.value >= 0.0,
        "restarted optimize_from lost the optimal seed: {}",
        restarted.value
    );
}

const N_INIT: usize = 6;
const ITERATIONS: usize = 10;
const TOTAL: usize = N_INIT + ITERATIONS;

/// The shared DE-driven Branin definition: every recording/replay below
/// uses an identical copy of this with its own observer.
fn branin_def(
    rec: RecordingObserver,
) -> limbo::bayes_opt::BoDef<Matern52, DataMean, Ei, Lhs, AdaptiveDe, MaxIterations> {
    BoDef::new(2)
        .acquisition(Ei::default())
        .init(Lhs { n: N_INIT })
        .inner_opt(AdaptiveDe::new(150).with_recorder(rec.de_recorder()))
        .refit(RefitSchedule::Never)
        .noise(1e-3)
        .seed(0xDE5EED)
        .iterations(ITERATIONS)
        .observer(rec)
}

/// Drive one full recorded Branin run (ask/tell + explicit finish) and
/// return its capture.
fn record_branin_run() -> RecordingObserver {
    let rec = RecordingObserver::new();
    let mut srv = branin_def(rec.clone()).build_server();
    let branin = Branin;
    for _ in 0..TOTAL {
        let x = srv.ask();
        srv.tell(&x, branin.eval(&x));
    }
    srv.finish();
    rec
}

/// Bit-exact comparison of two captures via the 17-digit JSONL line
/// format (stricter than `PartialEq` on f64, which conflates ±0.0).
fn assert_captures_identical(a: &RecordingObserver, b: &RecordingObserver, label: &str) {
    let (ea, eb) = (a.events(), b.events());
    assert_eq!(ea.len(), eb.len(), "{label}: event counts differ");
    for (i, (ra, rb)) in ea.iter().zip(&eb).enumerate() {
        assert_eq!(ra.to_json_line(), rb.to_json_line(), "{label}: capture diverges at event {i}");
    }
}

/// The acceptance criterion: a capture of a full DE-driven Branin run
/// replays bit-identically. `replay_into` re-asks every recorded
/// proposal from a fresh identically-configured server and compares
/// bit-for-bit; a second recorder on the replay server then confirms
/// the *entire* event stream (including the re-derived refit/init
/// events) matches the original, and the inner-DE generation rows were
/// captured on both sides.
#[test]
fn recorded_branin_run_replays_bit_identically() {
    let rec = record_branin_run();
    assert!(!rec.is_empty(), "recording captured no events");
    assert!(!rec.de_rows().is_empty(), "DE recorder captured no generations through the run");

    let replay_rec = RecordingObserver::new();
    let mut srv = branin_def(replay_rec.clone()).build_server();
    rec.replay_into(&mut srv).expect("replay diverged");
    assert_captures_identical(&rec, &replay_rec, "record vs replay");
}

/// save/load round-trip: the JSONL file format preserves every event
/// bit-exactly, and a loaded capture drives the same replay.
#[test]
fn saved_capture_round_trips_and_replays() {
    let rec = record_branin_run();
    let name = format!("limbo-de-replay-{}.jsonl", std::process::id());
    let path = std::env::temp_dir().join(name);
    rec.save(&path).expect("save capture");
    let loaded = RecordingObserver::load(&path).expect("load capture");
    std::fs::remove_file(&path).ok();
    assert_captures_identical(&rec, &loaded, "save/load round-trip");

    let replay_rec = RecordingObserver::new();
    let mut srv = branin_def(replay_rec.clone()).build_server();
    loaded.replay_into(&mut srv).expect("replay from loaded capture diverged");
    assert_captures_identical(&rec, &replay_rec, "loaded capture vs replay");
}

/// A replay against a *differently* configured study must fail loudly
/// at the first diverging proposal, naming the event index — that
/// error is the bisection point, not a silent pass.
#[test]
fn replay_against_a_different_seed_reports_divergence() {
    let rec = record_branin_run();
    let other = RecordingObserver::new();
    let mut srv = branin_def(other).seed(0xBAD5EED).build_server();
    let err = rec.replay_into(&mut srv).expect_err("divergent replay must fail");
    assert!(err.contains("diverged"), "error should name the divergence: {err}");
}
