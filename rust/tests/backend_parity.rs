//! Native-vs-XLA backend parity across dataset sizes, kernels and tiers —
//! the contract that lets the two GP backends be swapped freely. Skips
//! cleanly when `artifacts/` is absent.

use std::sync::Arc;

use limbo::coordinator::xla_model::XlaGpModel;
use limbo::kernel::{Kernel, Matern52, SquaredExpArd};
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, Model};
use limbo::rng::Pcg64;
use limbo::runtime::{find_artifact_dir, RtClient, XlaGp};

fn dataset(n: usize, dim: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Pcg64::seed(seed);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> =
        xs.iter().map(|x| (5.0 * x[0]).sin() + x.iter().sum::<f64>() * 0.3).collect();
    (xs, ys)
}

fn check_parity<K: Kernel>(kernel: K, kind: &str, n: usize, dim: usize) {
    let Some(dir) = find_artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let client = Arc::new(RtClient::cpu().expect("client"));
    let backend = match XlaGp::new(client, &dir, kind) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("skipping {kind}: {e}");
            return;
        }
    };
    let (xs, ys) = dataset(n, dim, 77);
    let mut native = Gp::new(kernel, DataMean::default(), 1e-2);
    native.fit(&xs, &ys);
    let mut xla = XlaGpModel::new(backend, dim);
    xla.loghp = native.xla_loghp();
    xla.fit(&xs, &ys);

    let mut rng = Pcg64::seed(5);
    for _ in 0..25 {
        let p = rng.unit_point(dim);
        let (mn, vn) = native.predict(&p);
        let (mx, vx) = xla.predict(&p);
        assert!(
            (mn - mx).abs() < 2e-3 * (1.0 + mn.abs()),
            "{kind} n={n} dim={dim}: mu {mn} vs {mx}"
        );
        assert!(
            (vn - vx).abs() < 2e-3 * (1.0 + vn.abs()),
            "{kind} n={n} dim={dim}: var {vn} vs {vx}"
        );
    }
}

#[test]
fn parity_matern52_across_tiers() {
    // crosses the 32 and 64 tier boundaries
    for n in [5, 31, 33, 63, 70] {
        check_parity(Matern52::new(2), "matern52", n, 2);
    }
}

#[test]
fn parity_se_ard() {
    for n in [10, 40] {
        check_parity(SquaredExpArd::new(2), "se_ard", n, 2);
    }
}

#[test]
fn parity_high_dim() {
    // d = 6 exercises feature padding to d_max = 8
    check_parity(Matern52::new(6), "matern52", 25, 6);
}

#[test]
fn parity_with_anisotropic_lengthscales() {
    let Some(_) = find_artifact_dir() else {
        return;
    };
    let mut k = Matern52::new(2);
    k.set_params(&[-0.7, 0.4, 0.2]); // distinct lengthscales + amplitude
    check_parity(k, "matern52", 20, 2);
}

#[test]
fn xla_lml_close_to_native() {
    let Some(dir) = find_artifact_dir() else {
        return;
    };
    let client = Arc::new(RtClient::cpu().expect("client"));
    let backend = Arc::new(XlaGp::new(client, &dir, "se_ard").expect("backend"));
    let (xs, ys) = dataset(18, 2, 9);
    let mut native = Gp::new(SquaredExpArd::new(2), DataMean::default(), 1e-2);
    native.learn_noise = true;
    native.fit(&xs, &ys);

    let flat: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
    let mean0 = ys.iter().sum::<f64>() / ys.len() as f64;
    let loghp = native.xla_loghp();
    let (lml_xla, grad_xla) = backend.lml_grad(&flat, &ys, 2, &loghp, mean0).expect("lml");
    let lml_native = native.log_marginal_likelihood();
    assert!(
        (lml_xla - lml_native).abs() < 1e-2 * (1.0 + lml_native.abs()),
        "lml {lml_xla} vs {lml_native}"
    );
    let grad_native = native.lml_grad();
    // layouts match: [log l1, log l2, log sf, log sn]
    for i in 0..4 {
        assert!(
            (grad_xla[i] - grad_native[i]).abs() < 5e-2 * (1.0 + grad_native[i].abs()),
            "grad[{i}]: {} vs {}",
            grad_xla[i],
            grad_native[i]
        );
    }
}

#[test]
fn xla_hp_opt_improves_lml() {
    let Some(dir) = find_artifact_dir() else {
        return;
    };
    let client = Arc::new(RtClient::cpu().expect("client"));
    let backend = Arc::new(XlaGp::new(client, &dir, "se_ard").expect("backend"));
    let mut rng = Pcg64::seed(31);
    let xs: Vec<Vec<f64>> = (0..25).map(|_| rng.unit_point(1)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (10.0 * x[0]).sin()).collect();

    let mut model = XlaGpModel::new(backend.clone(), 1);
    model.loghp = vec![1.5, 0.0, (0.05f64).ln()]; // badly mis-specified lengthscale
    model.fit(&xs, &ys);
    let flat: Vec<f64> = xs.iter().flat_map(|x| x.iter().copied()).collect();
    let m0 = ys.iter().sum::<f64>() / ys.len() as f64;
    let (before, _) = backend.lml_grad(&flat, &ys, 1, &model.loghp, m0).unwrap();
    model.optimize_hyperparams();
    let (after, _) = backend.lml_grad(&flat, &ys, 1, &model.loghp, m0).unwrap();
    assert!(after > before + 1.0, "XLA HPO should improve LML: {before} -> {after}");
    assert!(model.loghp[0] < 1.5, "lengthscale should shrink: {}", model.loghp[0]);
}
