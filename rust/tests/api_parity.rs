//! Cross-frontend parity: one `BoDef` + seed driven through (a) the
//! run-to-completion `BOptimizer`, (b) the sync `AskTellServer`
//! ask/tell loop, and (c) the spawned threaded `ServerHandle` must
//! produce **bit-identical** sample/observation traces.
//!
//! This is the regression net for the `BoCore` extraction: all three
//! frontends are thin drivers over the same engine, so any divergence —
//! a frontend growing its own incumbent rule, refit schedule, RNG
//! consumption order, or proposal path — shows up here as a trace
//! mismatch at the first differing bit.

use std::sync::{Mutex, MutexGuard};

use limbo::prelude::*;
use limbo::stat::TraceRow;

/// All tests in this binary serialize on one lock: the la-tuning test
/// mutates the process-global [`limbo::la::Tune`], and every other test's
/// bit-identity claim assumes the tuning does not change mid-run.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const N_INIT: usize = 6;
const ITERATIONS: usize = 10;
const TOTAL: usize = N_INIT + ITERATIONS;

/// The shared definition; every frontend gets an identical copy plus
/// its own trace subscriber. The refit schedule is part of the parity
/// surface (fires at n = 8 and n = 16 within the budget), with a small
/// single-restart hyper-opt so the test stays fast and deterministic.
fn def(
    trace: TraceHandle,
) -> limbo::bayes_opt::BoDef<
    Matern52,
    DataMean,
    Ei,
    RandomSampling,
    limbo::bayes_opt::DefaultInnerOpt,
    MaxIterations,
> {
    BoDef::new(2)
        .acquisition(Ei::default())
        .init_samples(N_INIT)
        .inner_opt(RandomPoint::new(64).then(NelderMead::default()).restarts(2, 2))
        .refit(RefitSchedule::Doubling { first: 8 })
        .hp_config(limbo::model::HpOptConfig { restarts: 1, iterations: 5, ..Default::default() })
        .noise(1e-3)
        .seed(0xC0FFEE)
        .iterations(ITERATIONS)
        .observer(trace)
}

fn objective(x: &[f64]) -> f64 {
    -(x[0] - 0.55).powi(2) - (x[1] - 0.35).powi(2) + 0.1 * (9.0 * x[0]).sin()
}

fn run_optimizer() -> Vec<TraceRow> {
    let trace = TraceHandle::new();
    let mut opt = def(trace.clone()).build_optimizer();
    let best = opt.optimize(&FnEval::new(2, objective));
    assert_eq!(best.evaluations, TOTAL);
    trace.rows()
}

fn run_sync_server() -> Vec<TraceRow> {
    let trace = TraceHandle::new();
    let mut srv = def(trace.clone()).build_server();
    for _ in 0..TOTAL {
        let x = srv.ask();
        let y = objective(&x);
        srv.tell(&x, y);
    }
    trace.rows()
}

fn run_threaded_server() -> Vec<TraceRow> {
    let trace = TraceHandle::new();
    let handle = def(trace.clone()).spawn_server();
    for _ in 0..TOTAL {
        let x = handle.ask();
        let y = objective(&x);
        handle.tell(x, y);
    }
    // tell() is fire-and-forget: join the server thread (drop sends
    // Shutdown and blocks) so the final observation is in the trace
    drop(handle);
    trace.rows()
}

/// Compare two traces bit-for-bit (`to_bits` — no epsilon anywhere).
fn assert_traces_identical(a: &[TraceRow], b: &[TraceRow], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: trace lengths differ");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.evaluations, rb.evaluations, "{label}: eval counter at row {i}");
        assert_eq!(ra.x.len(), rb.x.len(), "{label}: dim at row {i}");
        for (d, (va, vb)) in ra.x.iter().zip(&rb.x).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{label}: sample row {i} dim {d}: {va} vs {vb}"
            );
        }
        assert_eq!(
            ra.y.to_bits(),
            rb.y.to_bits(),
            "{label}: observation row {i}: {} vs {}",
            ra.y,
            rb.y
        );
        assert_eq!(
            ra.best.to_bits(),
            rb.best.to_bits(),
            "{label}: incumbent row {i}: {} vs {}",
            ra.best,
            rb.best
        );
    }
}

#[test]
fn optimizer_and_servers_produce_bit_identical_traces() {
    let _guard = lock();
    let opt = run_optimizer();
    assert_eq!(opt.len(), TOTAL);
    let sync = run_sync_server();
    let threaded = run_threaded_server();
    assert_traces_identical(&opt, &sync, "optimize vs sync ask/tell");
    assert_traces_identical(&opt, &threaded, "optimize vs threaded ask/tell");
}

#[test]
fn parity_holds_over_a_bounded_domain() {
    let _guard = lock();
    let run_opt = || {
        let trace = TraceHandle::new();
        let mut opt = def(trace.clone())
            .bounds(&[(-2.0, 6.0), (10.0, 30.0)])
            .refit(RefitSchedule::Never)
            .build_optimizer();
        let f = FnEval::new(2, |x: &[f64]| -(x[0] - 1.0).powi(2) - (0.1 * (x[1] - 20.0)).powi(2));
        opt.optimize(&f);
        trace.rows()
    };
    let run_srv = || {
        let trace = TraceHandle::new();
        let mut srv = def(trace.clone())
            .bounds(&[(-2.0, 6.0), (10.0, 30.0)])
            .refit(RefitSchedule::Never)
            .build_server();
        for _ in 0..TOTAL {
            let x = srv.ask();
            assert!((-2.0..=6.0).contains(&x[0]) && (10.0..=30.0).contains(&x[1]));
            let y = -(x[0] - 1.0).powi(2) - (0.1 * (x[1] - 20.0)).powi(2);
            srv.tell(&x, y);
        }
        trace.rows()
    };
    assert_traces_identical(&run_opt(), &run_srv(), "bounded optimize vs ask/tell");
}

#[test]
fn determinism_same_def_same_trace() {
    let _guard = lock();
    let a = run_optimizer();
    let b = run_optimizer();
    assert_traces_identical(&a, &b, "repeatability");
}

/// The observability layer must stay out of the deterministic trace:
/// spans only read clocks, never the RNG or the floating-point
/// evaluation order, so a run with the metrics registry enabled is
/// bit-identical to one with it disabled.
#[test]
fn metrics_on_or_off_leaves_traces_bit_identical() {
    let _guard = lock();
    // Serialize against other tests that toggle the global enabled flag
    // (the obs unit tests); the flag itself is what this test varies.
    let _obs_guard = limbo::obs::test_serial_guard();
    let prior = limbo::obs::enabled();
    limbo::obs::set_enabled(false);
    let off = run_optimizer();
    limbo::obs::set_enabled(true);
    let on = run_optimizer();
    limbo::obs::set_enabled(prior);
    assert_traces_identical(&off, &on, "metrics off vs on");
}

/// Degenerate-case parity: a `tell_observation` with **zero** noise
/// variance must normalize onto the exact-tell code path — same event
/// kind, same model update, same RNG consumption — so the traces agree
/// bit-for-bit with plain `tell`.
#[test]
fn zero_noise_tell_noisy_is_bit_identical_to_plain_tell() {
    let _guard = lock();
    let exact = run_sync_server();
    let noisy = {
        let trace = TraceHandle::new();
        let mut srv = def(trace.clone()).build_server();
        for _ in 0..TOTAL {
            let x = srv.ask();
            let y = objective(&x);
            srv.tell_observation(&Observation::noisy(x.clone(), y, 0.0)).unwrap();
        }
        trace.rows()
    };
    assert_traces_identical(&exact, &noisy, "tell vs tell_noisy(0.0)");
}

/// Degenerate-case parity: a constrained build with **zero** constraint
/// channels (an empty `ModelBank` + `PofWeighted`'s early return) must
/// trace bit-identically to the plain unconstrained server.
#[test]
fn zero_constraint_pof_weighted_is_bit_identical_to_plain_ei() {
    let _guard = lock();
    let plain = run_sync_server();
    let constrained = {
        let trace = TraceHandle::new();
        let mut srv = def(trace.clone()).constraints(0).build_constrained_server();
        for _ in 0..TOTAL {
            let x = srv.ask();
            let y = objective(&x);
            srv.tell(&x, y);
        }
        trace.rows()
    };
    assert_traces_identical(&plain, &constrained, "plain Ei vs zero-constraint PofWeighted<Ei>");
}

/// Degenerate-case parity: `async_pending` with a strictly alternating
/// ask/tell loop — the pending set is empty at every proposal, so the
/// kriging-believer fantasy path must collapse to the plain acquisition
/// maximization — traces bit-identically to the synchronous server.
#[test]
fn alternating_async_pending_is_bit_identical_to_synchronous() {
    let _guard = lock();
    let sync = run_sync_server();
    let pending = {
        let trace = TraceHandle::new();
        let mut srv = def(trace.clone()).async_pending(true).build_server();
        for _ in 0..TOTAL {
            let x = srv.ask();
            let y = objective(&x);
            srv.tell(&x, y);
        }
        trace.rows()
    };
    assert_traces_identical(&sync, &pending, "sync vs alternating async_pending");
}

/// The la thread-count knob must stay out of the deterministic trace:
/// parallel fan-outs only split disjoint output panels with fixed
/// per-element arithmetic, so a full optimizer run is bit-identical at
/// 1, 2, and 8 threads. (The `block`/`small` knobs are *not* swept here
/// — they legitimately pick different summation orders and are pinned
/// by the `<= 1e-12` parity tests in `blocked_la.rs` instead.)
#[test]
fn la_tuning_thread_count_leaves_traces_bit_identical() {
    let _guard = lock();
    let prior = limbo::la::tune();
    // force the blocked + parallel paths regardless of problem size so
    // the sweep actually exercises the fan-out code
    let forced = limbo::la::Tune { block: 8, small: 0, par_min_flops: 0, threads: 1 };
    limbo::la::set_tune(forced);
    let base = run_optimizer();
    for threads in [2, 8] {
        limbo::la::set_tune(limbo::la::Tune { threads, ..forced });
        let other = run_optimizer();
        assert_traces_identical(&base, &other, &format!("1 thread vs {threads} threads"));
    }
    limbo::la::set_tune(prior);
}

/// Every stochastic inner optimizer, wrapped in the `restarts`
/// combinator, must be bit-reproducible under a fixed seed across 1, 2
/// and 8 pool threads: the repeater forks one RNG stream per restart
/// index and folds results in restart order, so the thread count can
/// only change scheduling, never arithmetic. Covers both entry points
/// (`optimize` and the seed-forwarding `optimize_from`).
#[test]
fn inner_optimizers_are_bit_reproducible_across_pool_threads() {
    let _guard = lock();
    // multimodal enough that different restarts land in different basins
    let f = |x: &[f64]| {
        -x.iter().map(|&v| (v - 0.62) * (v - 0.62)).sum::<f64>() + 0.05 * (23.0 * x[0]).sin()
    };
    let x0 = [0.2, 0.8, 0.5];

    fn assert_same(a: &limbo::opt::Candidate, b: &limbo::opt::Candidate, what: &str) {
        assert_eq!(a.value.to_bits(), b.value.to_bits(), "{what}: value differs");
        assert_eq!(a.x.len(), b.x.len(), "{what}: dim differs");
        for (d, (va, vb)) in a.x.iter().zip(&b.x).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what}: x[{d}] differs");
        }
    }

    fn sweep<O: limbo::opt::Optimizer>(
        make: impl Fn() -> O,
        f: &dyn Objective,
        x0: &[f64],
        label: &str,
    ) {
        let run = |threads: usize| {
            let rep = make().restarts(4, threads);
            let mut rng = Pcg64::seed(0xA11CE);
            let free = rep.optimize(f, x0.len(), &mut rng);
            let seeded = rep.optimize_from(f, x0, &mut rng);
            (free, seeded)
        };
        let (base_free, base_seeded) = run(1);
        for threads in [2, 8] {
            let (free, seeded) = run(threads);
            assert_same(&base_free, &free, &format!("{label}/optimize @ {threads} threads"));
            let what = format!("{label}/optimize_from @ {threads} threads");
            assert_same(&base_seeded, &seeded, &what);
        }
    }

    sweep(|| limbo::opt::AdaptiveDe::new(300), &f, &x0, "adaptive_de");
    sweep(|| Cmaes::new(300), &f, &x0, "cmaes");
    sweep(|| PopulationSearch::new(10, 16), &f, &x0, "population_search");
    sweep(|| RandomPoint::new(64).then(NelderMead::default()), &f, &x0, "random+nelder_mead");
}

/// A DE-driven server definition for the metrics tests below: same
/// shape as [`def`], with the acquisition maximizer swapped for
/// [`limbo::opt::AdaptiveDe`] via the `inner_de` knob.
fn de_def(
    trace: TraceHandle,
) -> limbo::bayes_opt::BoDef<
    Matern52,
    DataMean,
    Ei,
    RandomSampling,
    limbo::opt::AdaptiveDe,
    MaxIterations,
> {
    BoDef::new(2)
        .acquisition(Ei::default())
        .init_samples(N_INIT)
        .inner_de(120)
        .refit(RefitSchedule::Never)
        .noise(1e-3)
        .seed(0xC0FFEE)
        .iterations(ITERATIONS)
        .observer(trace)
}

fn run_de_optimizer() -> Vec<TraceRow> {
    let trace = TraceHandle::new();
    let mut opt = de_def(trace.clone()).build_optimizer();
    let best = opt.optimize(&FnEval::new(2, objective));
    assert_eq!(best.evaluations, TOTAL);
    trace.rows()
}

/// `--metrics` must attribute DE time correctly: a DE-driven run books
/// one `Phase::InnerOpt` span per model-guided proposal and bumps the
/// DE generation/evaluation counters.
#[test]
fn de_runs_attribute_inner_opt_spans_and_counters() {
    let _guard = lock();
    let _obs_guard = limbo::obs::test_serial_guard();
    let prior = limbo::obs::enabled();
    limbo::obs::set_enabled(true);
    let base = limbo::obs::snapshot();
    run_de_optimizer();
    let delta = limbo::obs::snapshot().delta_since(&base);
    limbo::obs::set_enabled(prior);

    let inner_calls = delta.calls(limbo::obs::Phase::InnerOpt);
    assert!(
        inner_calls >= ITERATIONS as u64,
        "expected one InnerOpt span per model-guided proposal, got {inner_calls}"
    );
    let gens = delta.counter(limbo::obs::Counter::DeGenerations);
    let evals = delta.counter(limbo::obs::Counter::DeEvaluations);
    assert!(gens > 0, "DE generation counter never moved");
    assert!(evals >= gens, "DE evaluation counter ({evals}) below generation counter ({gens})");
}

/// Like [`metrics_on_or_off_leaves_traces_bit_identical`], for the DE
/// inner optimizer: its spans and counters must stay out of the
/// deterministic trace.
#[test]
fn de_metrics_on_or_off_leaves_traces_bit_identical() {
    let _guard = lock();
    let _obs_guard = limbo::obs::test_serial_guard();
    let prior = limbo::obs::enabled();
    limbo::obs::set_enabled(false);
    let off = run_de_optimizer();
    limbo::obs::set_enabled(true);
    let on = run_de_optimizer();
    limbo::obs::set_enabled(prior);
    assert_traces_identical(&off, &on, "DE metrics off vs on");
}
