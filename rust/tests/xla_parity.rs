//! Integration test: the AOT HLO artifacts produce the same numbers from
//! Rust (via PJRT) as the JAX graphs produced in Python.
//!
//! `python/compile/aot.py` dumps golden test vectors (inputs + expected
//! predict/ucb/lml outputs) into `artifacts/golden/`; here we replay them
//! through `runtime::XlaGp` and compare.  Requires `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use limbo::runtime::{RtClient, XlaGp};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn load_vec(dir: &PathBuf, name: &str) -> Vec<f64> {
    let path = dir.join("golden").join(format!("{name}.txt"));
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
        .split_whitespace()
        .map(|t| t.parse::<f64>().unwrap())
        .collect()
}

/// Golden inputs use 7 real points in 2 real dims, padded by python.
/// Reconstruct the *unpadded* views the Rust API expects.
struct Golden {
    x: Vec<f64>,    // [7 * 2]
    y: Vec<f64>,    // [7]
    xs: Vec<f64>,   // [64 * 2]
    loghp: Vec<f64>, // [4] = 2 lengthscales + sigma_f + sigma_n
    mean0: f64,
    alpha: f64,
}

fn load_golden(dir: &PathBuf) -> Golden {
    const N: usize = 7;
    const D: usize = 2;
    const D_MAX: usize = 8;
    const B: usize = 64;
    let xp = load_vec(dir, "x");
    let mut x = Vec::with_capacity(N * D);
    for i in 0..N {
        for j in 0..D {
            x.push(xp[i * D_MAX + j]);
        }
    }
    let xsp = load_vec(dir, "xs");
    let mut xs = Vec::with_capacity(B * D);
    for i in 0..B {
        for j in 0..D {
            xs.push(xsp[i * D_MAX + j]);
        }
    }
    let hp = load_vec(dir, "loghp");
    let loghp = vec![hp[0], hp[1], hp[D_MAX], hp[D_MAX + 1]];
    Golden {
        x,
        y: load_vec(dir, "y")[..N].to_vec(),
        xs,
        loghp,
        mean0: load_vec(dir, "mean0")[0],
        alpha: load_vec(dir, "alpha_ucb")[0],
    }
}

fn assert_close(actual: &[f64], expected: &[f64], tol: f64, what: &str) {
    assert_eq!(actual.len(), expected.len(), "{what}: length mismatch");
    for (i, (a, e)) in actual.iter().zip(expected).enumerate() {
        let scale = 1.0_f64.max(e.abs());
        assert!(
            (a - e).abs() <= tol * scale,
            "{what}[{i}]: got {a}, want {e} (tol {tol})"
        );
    }
}

#[test]
fn xla_artifacts_match_python_golden() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let golden = load_golden(&dir);
    let client = Arc::new(RtClient::cpu().expect("PJRT CPU client"));

    for kind in ["se_ard", "matern52"] {
        let gp = match XlaGp::new(client.clone(), &dir, kind) {
            Ok(gp) => gp,
            Err(e) => {
                eprintln!("skipping kind {kind}: {e}");
                continue;
            }
        };
        let (mu, var) = gp
            .predict(&golden.x, &golden.y, 2, &golden.xs, &golden.loghp, golden.mean0)
            .expect("predict");
        assert_close(&mu, &load_vec(&dir, &format!("{kind}_mu")), 1e-3, "mu");
        assert_close(&var, &load_vec(&dir, &format!("{kind}_var")), 1e-3, "var");

        let acq = gp
            .ucb(&golden.x, &golden.y, 2, &golden.xs, &golden.loghp, golden.mean0, golden.alpha)
            .expect("ucb");
        assert_close(&acq, &load_vec(&dir, &format!("{kind}_acq")), 1e-3, "acq");

        let (lml, grad) = gp
            .lml_grad(&golden.x, &golden.y, 2, &golden.loghp, golden.mean0)
            .expect("lml");
        assert_close(&[lml], &load_vec(&dir, &format!("{kind}_lml")), 1e-3, "lml");
        let gg = load_vec(&dir, &format!("{kind}_grad"));
        let expected_grad = vec![gg[0], gg[1], gg[8], gg[9]];
        assert_close(&grad, &expected_grad, 2e-2, "grad");
    }
}
