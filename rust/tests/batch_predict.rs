//! Property tests for the batch-first posterior pipeline:
//! `predict_batch` must be indistinguishable (≤ 1e-10) from per-point
//! `predict` for the dense, sparse, and adaptive model families across
//! random batch sizes, and the q-batch ask/tell path must propose
//! distinct points while converging like the sequential loop.

use limbo::bayes_opt::BoDef;
use limbo::coordinator::DefaultAskTellServer;
use limbo::kernel::{Exponential, Kernel, Matern52, SquaredExpArd};
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, AdaptiveModel, Model, SgpConfig, SparseGp};
use limbo::rng::Pcg64;

const TOL: f64 = 1e-10;

/// The service defaults (adaptive surrogate, no init design), spelled
/// through the declarative builder.
fn make_adaptive_server(dim: usize, seed: u64) -> DefaultAskTellServer {
    BoDef::service(dim).seed(seed).build_adaptive_server()
}

fn random_data(rng: &mut Pcg64, n: usize, dim: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(dim)).collect();
    let ys: Vec<f64> = xs
        .iter()
        .map(|x| (4.0 * x[0]).sin() + x.iter().sum::<f64>() * 0.3)
        .collect();
    (xs, ys)
}

/// Compare a model's batched posterior against its point-wise posterior
/// on `b` random candidates (includes off-data and near-data points).
fn assert_batch_matches<M: Model>(model: &M, rng: &mut Pcg64, b: usize, label: &str) {
    let dim = model.dim();
    let mut cands: Vec<Vec<f64>> = (0..b).map(|_| rng.unit_point(dim)).collect();
    if b > 2 {
        // out-of-hull candidate stresses the variance clamp
        cands[0] = vec![3.0; dim];
    }
    let batch = model.predict_batch(&cands);
    assert_eq!(batch.len(), cands.len(), "{label}: batch length");
    for (j, c) in cands.iter().enumerate() {
        let (mu, var) = model.predict(c);
        let scale = 1.0_f64.max(mu.abs());
        assert!(
            (batch[j].0 - mu).abs() <= TOL * scale,
            "{label}: mu[{j}] {} vs {mu}",
            batch[j].0
        );
        assert!(
            (batch[j].1 - var).abs() <= TOL * 1.0_f64.max(var.abs()),
            "{label}: var[{j}] {} vs {var}",
            batch[j].1
        );
    }
}

#[test]
fn dense_gp_predict_batch_equivalence() {
    for case in 0..24u64 {
        let mut rng = Pcg64::seed(0xD0_0000 + case);
        let dim = 1 + rng.below(3);
        let n = 1 + rng.below(48);
        let b = rng.below(40);
        let (xs, ys) = random_data(&mut rng, n, dim);
        // rotate kernels so every cross_cov specialization is exercised
        match case % 3 {
            0 => {
                let mut gp = Gp::new(Matern52::new(dim), DataMean::default(), 0.05);
                gp.fit(&xs, &ys);
                assert_batch_matches(&gp, &mut rng, b, "dense/matern52");
            }
            1 => {
                let mut gp = Gp::new(SquaredExpArd::new(dim), DataMean::default(), 0.05);
                gp.fit(&xs, &ys);
                assert_batch_matches(&gp, &mut rng, b, "dense/se_ard");
            }
            _ => {
                let mut gp = Gp::new(Exponential::new(dim), DataMean::default(), 0.05);
                gp.fit(&xs, &ys);
                assert_batch_matches(&gp, &mut rng, b, "dense/exponential");
            }
        }
    }
}

#[test]
fn dense_gp_batch_with_tuned_lengthscales() {
    // non-unit hyper-parameters stress the hoisted inverse lengthscales
    let mut rng = Pcg64::seed(0xD1);
    let (xs, ys) = random_data(&mut rng, 32, 2);
    let mut k = SquaredExpArd::new(2);
    k.set_params(&[-0.7, 0.4, 0.2]);
    let mut gp = Gp::new(k, DataMean::default(), 0.02);
    gp.fit(&xs, &ys);
    assert_batch_matches(&gp, &mut rng, 25, "dense/se_ard-tuned");
}

#[test]
fn sparse_gp_predict_batch_equivalence() {
    for case in 0..12u64 {
        let mut rng = Pcg64::seed(0x5CA0 + case);
        let dim = 1 + rng.below(3);
        let n = 30 + rng.below(90);
        let b = rng.below(40);
        let m = 8 + rng.below(24);
        let (xs, ys) = random_data(&mut rng, n, dim);
        let mut sgp = SparseGp::with_config(
            Matern52::new(dim),
            DataMean::default(),
            0.05,
            SgpConfig { max_inducing: m, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        assert_batch_matches(&sgp, &mut rng, b, "sparse/matern52");
    }
}

#[test]
fn adaptive_model_predict_batch_equivalence_both_regimes() {
    for case in 0..8u64 {
        let mut rng = Pcg64::seed(0xADA0 + case);
        let dim = 1 + rng.below(2);
        let b = 1 + rng.below(30);
        let (xs, ys) = random_data(&mut rng, 60, dim);

        // dense regime (threshold above the data size)
        let mut dense = AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 0.05)
            .with_threshold(1000);
        dense.fit(&xs, &ys);
        assert!(!dense.is_sparse());
        assert_batch_matches(&dense, &mut rng, b, "adaptive/dense");

        // sparse regime (migrated)
        let mut sparse = AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 0.05)
            .with_threshold(20)
            .with_sparse_config(SgpConfig { max_inducing: 16, ..SgpConfig::default() });
        sparse.fit(&xs, &ys);
        assert!(sparse.is_sparse());
        assert_batch_matches(&sparse, &mut rng, b, "adaptive/sparse");
    }
}

/// The joint posterior's covariance diagonal must reproduce
/// `predict_batch` variances (and the mean vector its means) to ≤ 1e-10,
/// and the covariance must be symmetric — for every model family.
fn assert_joint_matches_batch<M: Model>(model: &M, rng: &mut Pcg64, b: usize, label: &str) {
    let dim = model.dim();
    let cands: Vec<Vec<f64>> = (0..b.max(1)).map(|_| rng.unit_point(dim)).collect();
    let (mus, cov) = model.predict_joint(&cands);
    let batch = model.predict_batch(&cands);
    assert_eq!(mus.len(), cands.len(), "{label}: mean length");
    assert_eq!((cov.rows(), cov.cols()), (cands.len(), cands.len()), "{label}: cov shape");
    assert!(cov.is_symmetric(1e-12), "{label}: cov not symmetric");
    for j in 0..cands.len() {
        let scale = 1.0_f64.max(batch[j].0.abs());
        assert!(
            (mus[j] - batch[j].0).abs() <= TOL * scale,
            "{label}: joint mu[{j}] {} vs batch {}",
            mus[j],
            batch[j].0
        );
        assert!(
            (cov[(j, j)] - batch[j].1).abs() <= TOL * 1.0_f64.max(batch[j].1.abs()),
            "{label}: joint var[{j}] {} vs batch {}",
            cov[(j, j)],
            batch[j].1
        );
        // cross-covariances are bounded by the variances (Cauchy-Schwarz,
        // generous round-off slack)
        for k in 0..cands.len() {
            let bound = (cov[(j, j)] * cov[(k, k)]).sqrt() + 1e-8;
            assert!(
                cov[(j, k)].abs() <= bound + 1e-8,
                "{label}: cov[{j},{k}] {} exceeds CS bound {bound}",
                cov[(j, k)]
            );
        }
    }
}

#[test]
fn predict_joint_diag_parity_dense_sparse_adaptive() {
    for case in 0..16u64 {
        let mut rng = Pcg64::seed(0x1013 + case);
        let dim = 1 + rng.below(3);
        let b = 1 + rng.below(16);
        let (xs, ys) = random_data(&mut rng, 40 + rng.below(40), dim);

        let mut gp = Gp::new(Matern52::new(dim), DataMean::default(), 0.05);
        gp.fit(&xs, &ys);
        assert_joint_matches_batch(&gp, &mut rng, b, "joint/dense");

        let mut sgp = SparseGp::with_config(
            Matern52::new(dim),
            DataMean::default(),
            0.05,
            SgpConfig { max_inducing: 16, ..SgpConfig::default() },
        );
        sgp.fit(&xs, &ys);
        assert_joint_matches_batch(&sgp, &mut rng, b, "joint/sparse");

        let mut dense_adaptive =
            AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 0.05)
                .with_threshold(1000);
        dense_adaptive.fit(&xs, &ys);
        assert!(!dense_adaptive.is_sparse());
        assert_joint_matches_batch(&dense_adaptive, &mut rng, b, "joint/adaptive-dense");

        let mut sparse_adaptive =
            AdaptiveModel::new(Matern52::new(dim), DataMean::default(), 0.05)
                .with_threshold(20)
                .with_sparse_config(SgpConfig { max_inducing: 16, ..SgpConfig::default() });
        sparse_adaptive.fit(&xs, &ys);
        assert!(sparse_adaptive.is_sparse());
        assert_joint_matches_batch(&sparse_adaptive, &mut rng, b, "joint/adaptive-sparse");
    }
}

#[test]
fn empty_and_unfitted_models_batch_like_pointwise() {
    let mut rng = Pcg64::seed(0xE);
    let gp = Gp::new(Matern52::new(2), DataMean::default(), 0.05);
    assert_batch_matches(&gp, &mut rng, 5, "dense/empty");
    let sgp = SparseGp::new(Matern52::new(2), DataMean::default(), 0.05);
    assert_batch_matches(&sgp, &mut rng, 5, "sparse/empty");
    assert!(gp.predict_batch(&[]).is_empty());
    assert!(sgp.predict_batch(&[]).is_empty());
}

#[test]
fn ask_batch_q_distinct_and_convergence_parity() {
    let f = |x: &[f64]| -(x[0] - 0.55).powi(2) - (x[1] - 0.35).powi(2);
    let q = 4;

    // batched: 6 rounds of q=4 proposals
    let mut batched = make_adaptive_server(2, 31);
    for _ in 0..6 {
        let batch = batched.ask_batch(q);
        assert_eq!(batch.len(), q);
        for (i, a) in batch.iter().enumerate() {
            assert!(a.iter().all(|&v| (0.0..=1.0).contains(&v)));
            for b in batch.iter().skip(i + 1) {
                let d2: f64 = a.iter().zip(b).map(|(p, r)| (p - r) * (p - r)).sum();
                assert!(d2 > 1e-10, "coincident proposals {a:?} / {b:?}");
            }
        }
        for x in batch {
            let y = f(&x);
            batched.tell(&x, y);
        }
    }

    // sequential: same total budget, one point at a time
    let mut seq = make_adaptive_server(2, 31);
    for _ in 0..(6 * q) {
        let x = seq.ask();
        let y = f(&x);
        seq.tell(&x, y);
    }

    let (_, bv) = batched.best().unwrap();
    let (_, sv) = seq.best().unwrap();
    assert!(sv > -0.02, "sequential best={sv}");
    assert!(bv > -0.02, "batched best={bv} (parity with sequential)");
    assert!((bv - sv).abs() < 0.05, "parity gap: batched {bv} vs sequential {sv}");
}
