//! Integration tests for the `model/sgp` subsystem: parity of the FITC
//! sparse GP with the dense GP on the Branin benchmark (the subsystem's
//! acceptance bar), and end-to-end behavior of the adaptive surrogate.

use limbo::benchfns::{Branin, TestFunction};
use limbo::kernel::Matern52;
use limbo::mean::DataMean;
use limbo::model::{gp::Gp, AdaptiveModel, Model, SgpConfig, SparseGp};
use limbo::rng::Pcg64;

/// Standardized Branin training set (scale-free 1e-2 RMSE bar).
fn branin_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let f = Branin;
    let mut rng = Pcg64::seed(seed);
    let xs: Vec<Vec<f64>> = (0..n).map(|_| rng.unit_point(2)).collect();
    let raw: Vec<f64> = xs.iter().map(|x| f.eval(x)).collect();
    let mean = raw.iter().sum::<f64>() / n as f64;
    let var = raw.iter().map(|y| (y - mean).powi(2)).sum::<f64>() / n as f64;
    let std = var.sqrt().max(1e-12);
    let ys: Vec<f64> = raw.iter().map(|y| (y - mean) / std).collect();
    (xs, ys)
}

#[test]
fn sparse_matches_dense_on_branin_512_m128() {
    let (xs, ys) = branin_data(512, 0xB7A);

    let mut dense = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
    dense.fit(&xs, &ys);

    let mut sparse = SparseGp::with_config(
        Matern52::new(2),
        DataMean::default(),
        1e-2,
        SgpConfig { max_inducing: 128, ..SgpConfig::default() },
    );
    sparse.fit(&xs, &ys);
    assert_eq!(sparse.inducing_points().len(), 128);

    let mut rng = Pcg64::seed(0xCAFE);
    let probes = 256;
    let mut se = 0.0;
    for _ in 0..probes {
        let p = rng.unit_point(2);
        let (md, vd) = dense.predict(&p);
        let (ms, vs) = sparse.predict(&p);
        se += (md - ms) * (md - ms);
        assert!(vs.is_finite() && vs > 0.0);
        assert!(vd.is_finite() && vd > 0.0);
    }
    let rmse = (se / probes as f64).sqrt();
    assert!(rmse < 1e-2, "sparse vs dense prediction RMSE {rmse} exceeds the 1e-2 bar");
}

#[test]
fn sparse_posterior_actually_fits_branin() {
    // not just agreement with dense: the sparse posterior mean must track
    // the (standardized) function at held-out locations
    let (xs, ys) = branin_data(512, 0x5eed);
    let f = Branin;
    // recover the standardization used by branin_data
    let raw: Vec<f64> = xs.iter().map(|x| f.eval(x)).collect();
    let mean = raw.iter().sum::<f64>() / raw.len() as f64;
    let var = raw.iter().map(|y| (y - mean).powi(2)).sum::<f64>() / raw.len() as f64;
    let std = var.sqrt();

    let mut sparse = SparseGp::with_config(
        Matern52::new(2),
        DataMean::default(),
        1e-2,
        SgpConfig { max_inducing: 128, ..SgpConfig::default() },
    );
    sparse.fit(&xs, &ys);

    let mut rng = Pcg64::seed(0xF00);
    let mut se = 0.0;
    let probes = 128;
    for _ in 0..probes {
        let p = rng.unit_point(2);
        let truth = (f.eval(&p) - mean) / std;
        let (mu, _) = sparse.predict(&p);
        se += (mu - truth) * (mu - truth);
    }
    let rmse = (se / probes as f64).sqrt();
    assert!(rmse < 0.2, "sparse posterior vs Branin RMSE {rmse}");
}

#[test]
fn exact_fitc_hyperopt_beats_the_start_on_branin() {
    // end-to-end: large-budget sparse fit, then ML-II on the exact FITC
    // marginal likelihood (no dense-subset proxy) from a deliberately
    // mis-specified start — the fitted model must be strictly better on
    // its own objective and remain numerically healthy
    let (xs, ys) = branin_data(384, 0xF17C);
    let mut sparse = SparseGp::with_config(
        Matern52::new(2),
        DataMean::default(),
        0.3, // over-estimated noise: ML-II should shrink it
        SgpConfig { max_inducing: 64, ..SgpConfig::default() },
    );
    sparse.learn_noise = true;
    sparse.hp_opt.config.restarts = 2;
    sparse.hp_opt.config.iterations = 30;
    sparse.fit(&xs, &ys);
    let before = sparse.log_marginal_likelihood();
    sparse.optimize_hyperparams();
    let after = sparse.log_marginal_likelihood();
    assert!(after.is_finite());
    assert!(after > before, "exact FITC LML must not degrade: {before} -> {after}");
    // Branin is low-noise: the learned noise should have dropped
    assert!(
        sparse.noise_var() < 0.09,
        "noise variance {} should shrink below the 0.09 start",
        sparse.noise_var()
    );
    // the refit model still predicts sanely
    let mut rng = Pcg64::seed(3);
    for _ in 0..32 {
        let p = rng.unit_point(2);
        let (mu, var) = sparse.predict(&p);
        assert!(mu.is_finite() && var.is_finite() && var > 0.0);
    }
}

#[test]
fn adaptive_model_scales_through_migration() {
    // stream 400 Branin observations through an AdaptiveModel; it must
    // migrate at the threshold and keep a bounded inducing set while the
    // posterior stays usable
    let (xs, ys) = branin_data(400, 0xAD);
    let mut model = AdaptiveModel::new(Matern52::new(2), DataMean::default(), 1e-2)
        .with_threshold(128)
        .with_sparse_config(SgpConfig { max_inducing: 96, ..SgpConfig::default() });
    for (x, &y) in xs.iter().zip(&ys) {
        model.add_sample(x, y);
    }
    assert!(model.is_sparse());
    assert_eq!(model.n_samples(), 400);
    let sgp = model.as_sparse().expect("migrated");
    assert!(sgp.inducing_points().len() <= 96);

    // prediction agrees with a dense GP fit on the same data to the same
    // loose tolerance the BO loop cares about
    let mut dense = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
    dense.fit(&xs, &ys);
    let mut rng = Pcg64::seed(1);
    for _ in 0..64 {
        let p = rng.unit_point(2);
        let (md, _) = dense.predict(&p);
        let (ms, _) = model.predict(&p);
        assert!((md - ms).abs() < 0.15, "dense {md} vs adaptive-sparse {ms}");
    }
}
