//! End-to-end integration tests of the whole optimization stack:
//! convergence on the suite, static-vs-dynamic accuracy equivalence
//! (the paper's accuracy claim), determinism, stat traces, and the
//! ask/tell service composed with every major component family.

use limbo::acqui::{Ei, GpUcb, Ucb};
use limbo::bayes_opt::{BOptimizer, FnEval, RefitSchedule};
use limbo::benchfns::{self, TestFunction};
use limbo::benchlib::Summary;
use limbo::coordinator::experiment::BenchConfig;
use limbo::coordinator::fig1::{BaselineConfig, Fig1Settings, LimboConfig};
use limbo::init::Lhs;
use limbo::kernel::{Matern52, SquaredExpArd};
use limbo::mean::DataMean;
use limbo::model::gp::Gp;
use limbo::opt::{Cmaes, Direct, NelderMead, OptimizerExt, RandomPoint};
use limbo::stop::MaxIterations;

fn quick_bo(
    f: &dyn TestFunction,
    seed: u64,
    iterations: usize,
) -> limbo::bayes_opt::Best {
    let dim = f.dim();
    let gp = Gp::new(Matern52::new(dim), DataMean::default(), 1e-3);
    let mut opt = BOptimizer::new(
        gp,
        Ucb { alpha: 0.5 },
        Lhs { n: 10 },
        RandomPoint::new(256).then(NelderMead::default()).restarts(4, 2),
        MaxIterations(iterations),
        seed,
    );
    opt.optimize(&FnEval::new(dim, |x: &[f64]| f.eval(x)))
}

#[test]
fn converges_on_smooth_2d_functions() {
    // tolerances reflect the 45-evaluation budget with fixed unit
    // hyper-params (the paper's full protocol runs far longer + HPO)
    for (name, tol) in [("branin", 1.0), ("sphere", 0.01), ("six_hump_camel", 0.5)] {
        let f = benchfns::by_name(name, 2).unwrap();
        // median accuracy over several seeds must be tight
        let accs: Vec<f64> =
            (0..5).map(|s| f.accuracy(quick_bo(f.as_ref(), 100 + s, 35).value)).collect();
        let med = Summary::from(&accs).median;
        assert!(med < tol, "{name}: median accuracy {med} (runs: {accs:?})");
    }
}

#[test]
fn handles_higher_dimensions() {
    let f = benchfns::by_name("hartmann6", 6).unwrap();
    let accs: Vec<f64> =
        (0..3).map(|s| f.accuracy(quick_bo(f.as_ref(), 300 + s, 50).value)).collect();
    let med = Summary::from(&accs).median;
    // hartmann6 in 60 evals: getting within 0.7 of 3.32 is solid
    assert!(med < 0.7, "hartmann6 median accuracy {med}");
}

#[test]
fn static_and_dynamic_reach_equivalent_accuracy() {
    // The paper's claim: same algorithm, same accuracy (difference of
    // medians < ~2e-3 scale on converged smooth problems). We verify the
    // medians over seeds are statistically close on sphere.
    let f = benchfns::by_name("sphere", 2).unwrap();
    let settings = Fig1Settings { iterations: 30, inner_evals: 400, ..Default::default() };
    let limbo = LimboConfig::new(settings);
    let baseline = BaselineConfig::new(settings);
    let acc = |c: &dyn BenchConfig| -> f64 {
        let accs: Vec<f64> =
            (0..7).map(|s| f.accuracy(c.run(f.as_ref(), 500 + s).best_value)).collect();
        Summary::from(&accs).median
    };
    let a = acc(&limbo);
    let b = acc(&baseline);
    assert!(
        (a - b).abs() < 2e-2,
        "median accuracy gap too large: limbo {a:.4e} vs baseline {b:.4e}"
    );
}

#[test]
fn runs_are_deterministic_per_seed() {
    let f = benchfns::by_name("branin", 2).unwrap();
    let a = quick_bo(f.as_ref(), 77, 15);
    let b = quick_bo(f.as_ref(), 77, 15);
    assert_eq!(a.x, b.x);
    assert_eq!(a.value, b.value);
    let c = quick_bo(f.as_ref(), 78, 15);
    assert_ne!(a.x, c.x, "different seeds should explore differently");
}

#[test]
fn every_acquisition_composes_and_converges() {
    let f = benchfns::by_name("sphere", 2).unwrap();
    let run = |seed: u64, which: usize| -> f64 {
        let gp = Gp::new(SquaredExpArd::new(2), DataMean::default(), 1e-3);
        let inner = RandomPoint::new(128).then(NelderMead::default()).restarts(2, 2);
        let stop = MaxIterations(25);
        let eval = FnEval::new(2, |x: &[f64]| f.eval(x));
        let best = match which {
            0 => BOptimizer::new(gp, Ucb::default(), Lhs { n: 8 }, inner, stop, seed)
                .optimize(&eval),
            1 => BOptimizer::new(gp, Ei::default(), Lhs { n: 8 }, inner, stop, seed)
                .optimize(&eval),
            _ => BOptimizer::new(gp, GpUcb::default(), Lhs { n: 8 }, inner, stop, seed)
                .optimize(&eval),
        };
        f.accuracy(best.value)
    };
    for which in 0..3 {
        let acc = run(42, which);
        assert!(acc < 0.05, "acquisition #{which} accuracy {acc}");
    }
}

#[test]
fn hpo_improves_misscaled_problems() {
    // branin has values O(100): fixed unit-variance kernels are badly
    // mis-scaled, ML-II fixes the amplitude (measured ~10x accuracy gain).
    let f = benchfns::by_name("branin", 2).unwrap();
    let make = |hpo: bool, seed: u64| -> f64 {
        let mut gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-2);
        gp.hp_opt.config.restarts = 1;
        gp.hp_opt.config.iterations = 25;
        let mut opt = BOptimizer::new(
            gp,
            Ei::default(),
            Lhs { n: 10 },
            Direct::new(400),
            MaxIterations(30),
            seed,
        );
        if hpo {
            opt = opt.with_refit(RefitSchedule::Every(5));
        }
        f.accuracy(opt.optimize(&FnEval::new(2, |x: &[f64]| f.eval(x))).value)
    };
    let base: Vec<f64> = (0..5).map(|s| make(false, 900 + s)).collect();
    let hpo: Vec<f64> = (0..5).map(|s| make(true, 900 + s)).collect();
    let (mb, mh) = (Summary::from(&base).median, Summary::from(&hpo).median);
    assert!(
        mh <= mb,
        "HPO should help the mis-scaled problem: {mh} (hpo) vs {mb} (fixed)"
    );
}

#[test]
fn cmaes_inner_optimizer_full_stack() {
    let f = benchfns::by_name("branin", 2).unwrap();
    let gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-3);
    let mut opt = BOptimizer::new(
        gp,
        Ucb::default(),
        Lhs { n: 10 },
        Cmaes::new(300),
        MaxIterations(30),
        5,
    );
    let best = opt.optimize(&FnEval::new(2, |x: &[f64]| f.eval(x)));
    assert!(f.accuracy(best.value) < 0.5, "accuracy {}", f.accuracy(best.value));
}

#[test]
fn stat_traces_are_complete_and_monotone() {
    let dir = std::env::temp_dir().join("limbo_it_stats");
    let _ = std::fs::remove_dir_all(&dir);
    let f = benchfns::by_name("sphere", 2).unwrap();
    let gp = Gp::new(Matern52::new(2), DataMean::default(), 1e-3);
    let mut opt = BOptimizer::new(
        gp,
        Ucb::default(),
        Lhs { n: 5 },
        RandomPoint::new(64),
        MaxIterations(10),
        3,
    )
    .with_observer(limbo::stat::RunLogger::create(&dir).unwrap());
    let _ = opt.optimize(&FnEval::new(2, |x: &[f64]| f.eval(x)));

    let best = std::fs::read_to_string(dir.join("best.dat")).unwrap();
    let values: Vec<f64> = best
        .lines()
        .map(|l| l.split('\t').nth(1).unwrap().parse().unwrap())
        .collect();
    assert_eq!(values.len(), 15);
    for w in values.windows(2) {
        assert!(w[1] >= w[0], "best-so-far must be monotone: {values:?}");
    }
    let meta = std::fs::read_to_string(dir.join("meta.dat")).unwrap();
    assert!(meta.contains("evaluations\t15"));
}
